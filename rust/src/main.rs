//! FlashKAT leader binary.
//!
//! Subcommands:
//!   report <fig1|table1|table2|fig2|fig3|table3|table4|table5|configs|all>
//!          [--gpu 4060ti|h200] [--batch N] [--b-sim N] [--rows N] [--passes N]
//!   train  [--model kat_micro|vit_micro|kat_micro_katbwd] [--steps N]
//!          [--seed N] [--ckpt PATH] [--artifacts DIR]
//!   profile [--kernel fwd|kat|flash] [--loops N] [--gpu 4060ti|h200] [--batch N]
//!   profile-kernel [--rows N] [--d N] [--groups N] [--s-block N] [--iters N]
//!          [--seed N] [--gpu 4060ti|h200] [--out PATH]
//!          -- host-kernel roofline under the `probe` traffic counters:
//!             bit-identity gate, per-phase measured bytes/element and
//!             arithmetic intensity vs the gpusim analytic prediction
//!             (needs --features probe; writes BENCH_profile.json)
//!   serve-bench [--requests N] [--concurrency C] [--max-batch B] [--deadline-us D]
//!          [--model NAME | --models name:d[:groups],... | --pipeline TAG]
//!          [--autotune --slo-p99-us N] [--http --shards N] [--dup-frac F]
//!          [--cache-bytes N]
//!          -- dynamic micro-batching inference bench over named models or a
//!             whole AOT pipeline (writes BENCH_serve.json; --http also runs
//!             the workload over loopback HTTP and writes BENCH_http.json;
//!             --cache-bytes runs cached-vs-uncached legs over all three
//!             transports and writes BENCH_cache.json)
//!   serve-http [--addr A] [--port P|0] [--shards N] [--cache-bytes N]
//!          [--models name:d[:groups],... | --pipeline TAG]
//!          -- HTTP/JSON serving frontend; runs until SIGTERM, then drains
//!   trace-stat [--json] PATH   -- sanity-scan a Perfetto trace written by
//!          --trace-out (packet/slice/counter + per-track event counts)
//!   selfcheck [--artifacts DIR]   -- runtime vs Rust-oracle numerics
//!   flops
//!
//! See DESIGN.md §5 for the table/figure -> command mapping.

use anyhow::{anyhow, bail, Context, Result};

use flashkat::cli::Args;
use flashkat::config::TrainConfig;
use flashkat::coordinator::Trainer;
use flashkat::gpusim::kernels::{
    RationalBwdFlashKernel, RationalBwdKatKernel, RationalDims, RationalFwdKernel,
};
use flashkat::gpusim::{simulate, GpuConfig};
use flashkat::rational::experiment::RoundingConfig;
use flashkat::report;
use flashkat::runtime::Runtime;

fn gpu_from(args: &Args) -> Result<GpuConfig> {
    Ok(match args.flag_str("gpu", "4060ti") {
        "4060ti" => GpuConfig::rtx4060ti(),
        "h200" => GpuConfig::h200(),
        other => bail!("unknown --gpu {other:?} (4060ti|h200)"),
    })
}

fn dims_from(args: &Args) -> Result<RationalDims> {
    let mut d = RationalDims::paper();
    d.batch = args.flag_u64("batch", d.batch)?;
    Ok(d)
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let gpu = gpu_from(args)?;
    // fig1/table4 reproduce the paper's H200 end-to-end measurements, so
    // they default to the H200 preset — but an *explicit* --gpu is the
    // user's call and must be honored, not silently overridden.
    let gpu_e2e = if args.flag("gpu").is_some() { gpu.clone() } else { GpuConfig::h200() };
    let b_sim = args.flag_u64("b-sim", 32)?;
    // Simulated-batch cost for fig1/table4 grows superlinearly; clamp
    // loudly instead of silently — but only when one of those reports is
    // actually selected, so unrelated reports don't warn about a flag
    // they never read.
    let runs_e2e = matches!(which, "all" | "fig1" | "table4");
    let b_sim_e2e = if b_sim > 16 && runs_e2e {
        eprintln!(
            "warning: --b-sim {b_sim} clamped to 16 for fig1/table4 \
             (whole-model simulation cost; pass --b-sim <= 16 to silence)"
        );
        16
    } else {
        b_sim.min(16)
    };
    let dims = dims_from(args)?;
    let rounding = RoundingConfig {
        rows: args.flag_usize("rows", 32 * 768)?,
        passes: args.flag_usize("passes", 5)?,
        ..Default::default()
    };
    let all = which == "all";
    if all || which == "table1" {
        print!("{}", report::table1());
    }
    if all || which == "fig1" {
        print!("{}", report::fig1(&gpu_e2e, b_sim_e2e));
    }
    if all || which == "table2" {
        print!("{}", report::table2(&gpu, dims));
    }
    if all || which == "fig2" || which == "fig3" {
        print!("{}", report::fig2_fig3(&gpu, dims));
    }
    if all || which == "table3" {
        print!("{}", report::table3(&gpu, dims));
    }
    if all || which == "table4" {
        print!("{}", report::table4(&gpu_e2e, b_sim_e2e));
    }
    if all || which == "table5" {
        print!("{}", report::table5(&rounding));
    }
    if all || which == "configs" {
        print!("{}", report::configs());
    }
    if !all
        && !matches!(
            which,
            "table1" | "fig1" | "table2" | "fig2" | "fig3" | "table3" | "table4" | "table5"
                | "configs"
        )
    {
        bail!("unknown report {which:?}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let tag = args.flag_str("model", "kat_micro").to_string();
    let mut cfg = TrainConfig { model: tag.clone(), ..Default::default() };
    cfg.steps = args.flag_usize("steps", cfg.steps)?;
    cfg.seed = args.flag_u64("seed", cfg.seed)?;
    cfg.log_every = args.flag_usize("log-every", cfg.log_every)?;
    let artifacts = args.flag_str("artifacts", "artifacts");
    let rt = Runtime::cpu(artifacts)?;
    eprintln!("platform: {}", rt.platform());
    let trainer = Trainer::new(&rt, &tag, cfg).context("loading artifacts")?;
    eprintln!(
        "model {tag}: {} parameter leaves, batch {}",
        trainer.param_leaves(),
        trainer.batch_size()
    );
    let ckpt = args.flag("ckpt").map(std::path::PathBuf::from);
    let rep = trainer.train(ckpt.as_deref())?;
    println!(
        "{}: {} steps, loss {:.4} -> {:.4}, {:.1} (± {:.1}) img/s, host overhead {:.1}%, eval acc {:.3} (EMA {:.3})",
        rep.tag,
        rep.steps,
        rep.first_loss(),
        rep.final_loss(),
        rep.throughput_mean,
        rep.throughput_ci95,
        100.0 * rep.host_overhead,
        rep.final_eval_acc.unwrap_or(f64::NAN),
        rep.ema_eval_acc.unwrap_or(f64::NAN)
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let gpu = gpu_from(args)?;
    let mut dims = dims_from(args)?;
    // Range-checked: a loop count beyond u32 is an error, not a silent
    // `as u32` truncation to some unrelated small value.
    dims.flop_loops = args.flag_u32("loops", 1)?;
    let rep = match args.flag_str("kernel", "kat") {
        "fwd" => simulate(&gpu, &RationalFwdKernel::new(dims)),
        "kat" => simulate(&gpu, &RationalBwdKatKernel::new(dims)),
        "flash" => simulate(&gpu, &RationalBwdFlashKernel::new(dims)),
        other => bail!("unknown --kernel {other:?} (fwd|kat|flash)"),
    };
    println!("kernel                    cycles       time   SM%      L1%      L2%     HBM%");
    println!("{}", rep.table_row());
    print!("{}", rep.warp_state_figure());
    Ok(())
}

/// Kernel memory-traffic roofline profile (DESIGN.md §17): run the host
/// rational kernels under the `probe` feature's traffic counters, time
/// the forward / fused-backward / reduce phases, compute measured
/// bytes/element and arithmetic intensity, compare the measured traffic
/// against the analytic per-element traffic `gpusim` predicts for the
/// matching kernels, and write `BENCH_profile.json` with a
/// `predicted_vs_measured` error block.  Refuses to run on a build
/// without `--features probe` (the counters would read all-zero).
fn cmd_profile_kernel(args: &Args) -> Result<()> {
    use flashkat::probe::{self, Phase, Snapshot, Stream};
    use flashkat::rational::accumulate::{backward, Strategy};
    use flashkat::rational::{forward, kernel, Coeffs};
    use flashkat::util::json::Json;
    use flashkat::util::rng::Pcg64;
    use std::time::Instant;

    if !Snapshot::enabled() {
        bail!(
            "profile-kernel needs a build with --features probe \
             (the default build compiles the kernel traffic counters to no-ops)"
        );
    }
    let rows = args.flag_usize("rows", 4096)?.max(1);
    let d = args.flag_usize("d", 768)?.max(1);
    let groups = args.flag_usize("groups", 8)?.max(1);
    if d % groups != 0 {
        bail!("--d {d} must be divisible by --groups {groups}");
    }
    let s_block = args.flag_usize("s-block", 128)?.max(1);
    let iters = args.flag_usize("iters", 3)?.max(1);
    let seed = args.flag_u64("seed", 7)?;
    let gpu_name = args.flag_str("gpu", "4060ti").to_string();
    let gpu = gpu_from(args)?;
    let out = args.flag_str("out", "BENCH_profile.json");

    let mut rng = Pcg64::new(seed);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let coeffs = Coeffs::<f32>::randn(groups, 6, 4, &mut rng);
    let strategy = Strategy::BlockTree { s_block };

    // Bit-identity gate: with probes compiled in, two identical kernel
    // invocations must still produce bitwise-identical outputs — the
    // counters may only ever touch their own atomics, never the floats.
    let y0 = forward(&x, rows, d, &coeffs);
    let y1 = forward(&x, rows, d, &coeffs);
    let (dx0, da0, db0) = backward(&x, &dout, rows, d, &coeffs, strategy);
    let (dx1, da1, db1) = backward(&x, &dout, rows, d, &coeffs, strategy);
    let bits = |a: &[f32], b: &[f32]| {
        a.len() == b.len() && a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
    };
    if !(bits(&y0, &y1) && bits(&dx0, &dx1) && bits(&da0, &da1) && bits(&db0, &db1)) {
        bail!("bit identity FAIL: probed kernels are not run-to-run deterministic");
    }
    println!(
        "bit identity PASS ({} kernel, {rows}x{d}, {groups} groups, s_block {s_block})",
        kernel::variant()
    );

    // Measured traffic: snapshot deltas around timed runs.  Other
    // threads are idle here, so the delta is this workload's traffic.
    let fwd_base = probe::snapshot();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(forward(&x, rows, d, &coeffs));
    }
    let fwd_secs = t0.elapsed().as_secs_f64() / iters as f64;
    let fwd = probe::snapshot().delta_since(&fwd_base);

    let bwd_base = probe::snapshot();
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(backward(&x, &dout, rows, d, &coeffs, strategy));
    }
    let bwd_secs = t0.elapsed().as_secs_f64() / iters as f64;
    let bwd = probe::snapshot().delta_since(&bwd_base);

    let elems = (iters * rows * d) as f64;
    let fwd_bpe = fwd.phase_bytes(Phase::Forward) as f64 / elems;
    let bwd_fused_bpe = bwd.phase_bytes(Phase::Backward) as f64 / elems;
    let reduce_bpe = bwd.phase_bytes(Phase::Reduce) as f64 / elems;
    let bwd_bpe = bwd_fused_bpe + reduce_bpe;

    // Analytic prediction from the gpusim kernel models: HBM bytes per
    // element for the forward kernel and the Algorithm-2 (block-tree)
    // backward at the same s_block.
    let dims = RationalDims {
        batch: rows as u64,
        seq: 1,
        d: d as u64,
        n_groups: groups as u32,
        m1: 6,
        n: 4,
        flop_loops: 1,
    };
    let fwd_pred = simulate(&gpu, &RationalFwdKernel::new(dims)).bytes_hbm as f64
        / dims.elements() as f64;
    let mut flash = RationalBwdFlashKernel::new(dims);
    flash.s_block = s_block as u64;
    let bwd_pred = simulate(&gpu, &flash).bytes_hbm as f64 / dims.elements() as f64;
    let rel = |measured: f64, predicted: f64| (measured - predicted).abs() / predicted;

    let fwd_ai = dims.fwd_flops_per_elem() as f64 / fwd_bpe.max(f64::MIN_POSITIVE);
    let bwd_ai = dims.bwd_flops_per_elem() as f64 / bwd_bpe.max(f64::MIN_POSITIVE);
    println!(
        "forward : {fwd_bpe:7.2} B/elem measured vs {fwd_pred:7.2} predicted \
         (rel err {:.3}), AI {fwd_ai:.2} flop/B, {:.1} ms/iter",
        rel(fwd_bpe, fwd_pred),
        1e3 * fwd_secs
    );
    println!(
        "backward: {bwd_bpe:7.2} B/elem measured ({bwd_fused_bpe:.2} fused + {reduce_bpe:.2} \
         reduce) vs {bwd_pred:7.2} predicted (rel err {:.3}), AI {bwd_ai:.2} flop/B, {:.1} ms/iter",
        rel(bwd_bpe, bwd_pred),
        1e3 * bwd_secs
    );
    // Combined per-phase table for the console (fwd and bwd deltas are
    // disjoint in phase space, so a plain field-wise sum is the union).
    let mut total = fwd.clone();
    for p in 0..Phase::COUNT {
        for s in 0..Stream::COUNT {
            total.loads[p][s] += bwd.loads[p][s];
            total.stores[p][s] += bwd.stores[p][s];
        }
    }
    total.run_flushes += bwd.run_flushes;
    total.spill_falls += bwd.spill_falls;
    total.masked_tail_lanes += bwd.masked_tail_lanes;
    print!("{}", probe_summary(&total));

    // The artifact: per-phase measured traffic with stream breakdowns,
    // the gpusim prediction, and the relative error CI gates on.
    let streams_json = |snap: &probe::Snapshot, p: Phase| {
        Json::Obj(
            Stream::ALL
                .iter()
                .map(|&s| {
                    (
                        s.name().to_string(),
                        Json::Obj(vec![
                            ("loaded".to_string(), Json::Int(snap.loaded(p, s) as i64)),
                            ("stored".to_string(), Json::Int(snap.stored(p, s) as i64)),
                        ]),
                    )
                })
                .collect(),
        )
    };
    let phase_json = |name: &str, snap: &probe::Snapshot, p: Phase, secs: f64, bpe: f64, ai: f64| {
        (
            name.to_string(),
            Json::Obj(vec![
                ("secs_per_iter".to_string(), Json::Num(secs)),
                ("bytes".to_string(), Json::Int(snap.phase_bytes(p) as i64)),
                ("bytes_per_elem".to_string(), Json::Num(bpe)),
                ("arithmetic_intensity".to_string(), Json::Num(ai)),
                ("streams".to_string(), streams_json(snap, p)),
            ]),
        )
    };
    let pvm = |predicted: f64, measured: f64| {
        Json::Obj(vec![
            ("predicted_bytes_per_elem".to_string(), Json::Num(predicted)),
            ("measured_bytes_per_elem".to_string(), Json::Num(measured)),
            ("rel_error".to_string(), Json::Num(rel(measured, predicted))),
        ])
    };
    let json = Json::Obj(vec![
        ("schema".to_string(), Json::Str("flashkat-profile-v1".to_string())),
        (
            "config".to_string(),
            Json::Obj(vec![
                ("rows".to_string(), Json::Int(rows as i64)),
                ("d".to_string(), Json::Int(d as i64)),
                ("groups".to_string(), Json::Int(groups as i64)),
                ("s_block".to_string(), Json::Int(s_block as i64)),
                ("iters".to_string(), Json::Int(iters as i64)),
                ("seed".to_string(), Json::Int(seed as i64)),
                ("gpu".to_string(), Json::Str(gpu_name)),
                ("variant".to_string(), Json::Str(kernel::variant().to_string())),
            ]),
        ),
        ("bit_identity".to_string(), Json::Str("PASS".to_string())),
        (
            "phases".to_string(),
            Json::Obj(vec![
                phase_json("forward", &fwd, Phase::Forward, fwd_secs, fwd_bpe, fwd_ai),
                phase_json(
                    "backward",
                    &bwd,
                    Phase::Backward,
                    bwd_secs,
                    bwd_fused_bpe,
                    dims.bwd_flops_per_elem() as f64 / bwd_fused_bpe.max(f64::MIN_POSITIVE),
                ),
                phase_json("reduce", &bwd, Phase::Reduce, 0.0, reduce_bpe, 0.0),
            ]),
        ),
        (
            "events".to_string(),
            Json::Obj(vec![
                ("run_flushes".to_string(), Json::Int(total.run_flushes as i64)),
                ("spill_falls".to_string(), Json::Int(total.spill_falls as i64)),
                ("masked_tail_lanes".to_string(), Json::Int(total.masked_tail_lanes as i64)),
            ]),
        ),
        (
            "predicted_vs_measured".to_string(),
            Json::Obj(vec![
                ("forward".to_string(), pvm(fwd_pred, fwd_bpe)),
                ("backward".to_string(), pvm(bwd_pred, bwd_bpe)),
            ]),
        ),
    ]);
    std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// `--models name:d[:groups],...` (or the single `--model`/`--d`/
/// `--groups` flags) → the rational-model registry to serve.
fn serve_model_specs(args: &Args) -> Result<Vec<flashkat::serve::ModelSpec>> {
    use flashkat::serve::ModelSpec;
    let default_d = args.flag_usize("d", 256)?;
    let default_groups = args.flag_usize("groups", 8)?.max(1);
    let list = args.flag_list("models");
    if list.is_empty() {
        // An explicitly passed but empty --models must not silently fall
        // back to the single-model flags (and their laxer checks).
        if args.flag("models").is_some() {
            bail!("--models was given but names no models (want name:d[:groups],...)");
        }
        return Ok(vec![ModelSpec::new(
            args.flag_str("model", "grkan"),
            default_d,
            default_groups,
        )]);
    }
    // With an explicit registry these single-model flags would be
    // silently dead; reject instead (--groups stays meaningful as the
    // default for name:d entries).
    if args.flag("model").is_some() {
        bail!("--model and --models are mutually exclusive");
    }
    if args.flag("d").is_some() {
        bail!("--d is ignored with --models; widths are per entry (name:d[:groups])");
    }
    let specs: Vec<ModelSpec> = list
        .iter()
        .map(|item| {
            let parse_n = |v: &str, what: &str| {
                v.parse::<usize>()
                    .map_err(|_| anyhow!("--models {item:?}: bad {what} {v:?}"))
            };
            let parts: Vec<&str> = item.split(':').collect();
            match parts.as_slice() {
                [name, d] => Ok(ModelSpec::new(*name, parse_n(d, "width")?, default_groups)),
                [name, d, g] => {
                    Ok(ModelSpec::new(*name, parse_n(d, "width")?, parse_n(g, "group count")?))
                }
                _ => bail!("--models entries are name:d[:groups], got {item:?}"),
            }
        })
        .collect::<Result<_>>()?;
    // Models route by name, so a repeated name cannot mean anything the
    // user wants: one entry would shadow the other.  Reject at the CLI
    // with the offending entry named, instead of letting the registry
    // validation fail later with less context.
    for (i, s) in specs.iter().enumerate() {
        if let Some(first) = specs[..i].iter().find(|o| o.name == s.name) {
            bail!(
                "--models names {:?} twice ({}:{} and {}:{}); registry names route requests and must be unique",
                s.name,
                first.name,
                first.d,
                s.name,
                s.d
            );
        }
    }
    Ok(specs)
}

/// `--trace-out base.pftrace` writes one trace file per bench leg; the
/// per-leg name inserts the leg tag before the extension
/// (`base-http.pftrace`) so all legs land next to the BENCH JSON.
fn trace_leg_path(base: &str, leg: &str) -> String {
    match base.rsplit_once('.') {
        Some((stem, ext)) if !stem.is_empty() && !stem.ends_with('/') => {
            format!("{stem}-{leg}.{ext}")
        }
        _ => format!("{base}-{leg}"),
    }
}

/// Render a collector to `path`, self-scan the bytes (a trace we cannot
/// parse back must fail the run, not load blank in the UI), and return
/// the record for the bench JSON's `tracing` section.
fn write_trace(
    tracer: &flashkat::trace::TraceCollector,
    path: &str,
) -> Result<flashkat::serve::TraceRun> {
    let bytes = tracer.render();
    let stat = flashkat::trace::stat(&bytes)
        .map_err(|e| anyhow!("rendered trace failed self-scan: {e}"))?;
    std::fs::write(path, &bytes).with_context(|| format!("writing {path}"))?;
    let dropped = tracer.dropped();
    if dropped > 0 {
        eprintln!("warning: {dropped} trace events dropped (ring capacity); {path} is partial");
    }
    println!("wrote trace {path} ({} packets, {} bytes)", stat.packets, bytes.len());
    Ok(flashkat::serve::TraceRun {
        path: path.to_string(),
        packets: stat.packets,
        bytes: bytes.len(),
    })
}

/// Dynamic micro-batching inference benchmark: drive the serve subsystem
/// with a seeded workload at the requested policy — against one or more
/// named rational models (`--models`) or a whole AOT-compiled pipeline
/// (`--pipeline <tag>`) — compare against an unbatched (`max-batch 1`)
/// baseline or sweep policies (`--autotune`), and persist the
/// `BENCH_serve.json`-shaped record.  `--trace-out PATH` additionally
/// captures Perfetto traces (per leg for the transport modes) and an
/// in-process traced-vs-untraced overhead measurement.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    // --profile wraps the whole bench (any leg combination) in a kernel
    // traffic-probe snapshot delta and prints the per-phase byte totals
    // after the run.  The counters are no-ops without the feature, so a
    // default build must refuse the flag rather than print zeros.
    let profile = args.flag_bool("profile");
    if profile && !flashkat::probe::Snapshot::enabled() {
        bail!(
            "--profile needs a build with --features probe \
             (the default build compiles the kernel traffic counters to no-ops)"
        );
    }
    let base = profile.then(flashkat::probe::snapshot);
    cmd_serve_bench_inner(args)?;
    if let Some(base) = base {
        print!("{}", probe_summary(&flashkat::probe::snapshot().delta_since(&base)));
    }
    Ok(())
}

/// Human-readable per-phase table of a probe snapshot delta, shared by
/// `serve-bench --profile` and `profile-kernel`.
fn probe_summary(d: &flashkat::probe::Snapshot) -> String {
    use flashkat::probe::{Phase, Stream};
    let mut out = String::new();
    out.push_str("kernel traffic probes:\n");
    for p in Phase::ALL {
        let streams: Vec<String> = Stream::ALL
            .iter()
            .filter_map(|&s| {
                let (l, st) = (d.loaded(p, s), d.stored(p, s));
                (l + st > 0).then(|| format!("{s} {}B", l + st))
            })
            .collect();
        out.push_str(&format!(
            "  {p:<8} {:>14} B  ({})\n",
            d.phase_bytes(p),
            if streams.is_empty() { "idle".to_string() } else { streams.join(", ") }
        ));
    }
    out.push_str(&format!(
        "  events: {} run flushes, {} spill falls, {} masked tail lanes, {} threads\n",
        d.run_flushes, d.spill_falls, d.masked_tail_lanes, d.threads
    ));
    out
}

fn cmd_serve_bench_inner(args: &Args) -> Result<()> {
    use flashkat::serve::{loadgen, Arrival, BatchPolicy, LoadConfig, ModelExecutor, ModelSpec};
    use flashkat::trace::TraceCollector;
    use flashkat::util::json::Json;
    use std::sync::Arc;

    let requests = args.flag_usize("requests", 2000)?.max(1);
    let concurrency = args.flag_usize("concurrency", 16)?.max(1);
    let max_batch = args.flag_usize("max-batch", 64)?.max(1);
    let deadline_us = args.flag_u64("deadline-us", 200)?;
    let queue_depth = args.flag_usize("queue-depth", 1024)?.max(1);
    let arrival = if args.flag_bool("open-loop") {
        Arrival::Open { rate_rps: args.flag_f64("rate", 5000.0)? }
    } else {
        Arrival::Closed
    };
    // --cache-bytes N switches serve-bench into the cached-vs-uncached
    // comparison mode; a cache bench over a workload with no repeats
    // can only miss, so the duplicate knob defaults to a repeat-heavy
    // mix there (and to the historical 0.0 everywhere else).
    let cache_mode = args.flag("cache-bytes").is_some();
    let cache_bytes = args.flag_usize("cache-bytes", 0)?;
    let dup_frac = args.flag_f64("dup-frac", if cache_mode { 0.5 } else { 0.0 })?;
    if !(0.0..=1.0).contains(&dup_frac) {
        bail!("--dup-frac {dup_frac} out of range (want a fraction in [0, 1])");
    }
    let mut cfg = LoadConfig {
        requests,
        concurrency,
        seed: args.flag_u64("seed", 7)?,
        arrival,
        dup_frac,
        ..Default::default()
    };
    let policy = BatchPolicy {
        max_batch,
        deadline_us,
        queue_depth,
        eager: !args.flag_bool("no-eager"),
    };
    let out = args.flag_str("out", "BENCH_serve.json");
    let autotune = args.flag_bool("autotune");
    let slo_p99_us = args.flag_u64("slo-p99-us", 2000)?;
    if !autotune && args.flag("slo-p99-us").is_some() {
        bail!("--slo-p99-us only applies with --autotune");
    }
    let trace_out = args.flag("trace-out");
    if autotune && trace_out.is_some() {
        bail!("--trace-out and --autotune are mutually exclusive (trace one policy, not a sweep)");
    }
    // Append the `tracing` section to a bench artifact in place.
    let push_tracing = |json: &mut Json, section: Json| {
        if let Json::Obj(fields) = json {
            fields.push(("tracing".to_string(), section));
        }
    };
    if args.flag("policy").is_some() && args.flag("nodes").is_none() {
        bail!("--policy only applies with --nodes (or the route command)");
    }

    // --nodes N: the flashroute multi-node scaling comparison (DESIGN.md
    // §18).  Two legs — the identical seeded workload through a router
    // over 1 backend node and over N nodes, every node carrying the full
    // replicated registry — plus a serial bit-identity replay through
    // the router against the unbatched oracle.  Writes BENCH_route.json
    // with the scaling-efficiency block.
    if args.flag("nodes").is_some() {
        use flashkat::route::RoutePolicy;
        let nodes = args.flag_usize("nodes", 2)?;
        if nodes < 2 {
            bail!("--nodes wants at least 2 (the 1-node leg runs automatically for comparison)");
        }
        if cache_mode {
            bail!("--nodes and --cache-bytes are mutually exclusive (bench the cache on one node)");
        }
        if args.flag_bool("http") || args.flag_bool("wire") {
            bail!("--nodes runs its own wire legs through the router; drop --http/--wire");
        }
        if autotune {
            bail!("--nodes and --autotune are mutually exclusive (autotune a single node first)");
        }
        if args.flag("pipeline").is_some() {
            bail!("--nodes benches the rational registry; --pipeline has no routed path yet");
        }
        if trace_out.is_some() {
            bail!("--trace-out and --nodes are mutually exclusive (trace `flashkat route` instead)");
        }
        let policy_s = args.flag_str("policy", "ring");
        let route_policy = RoutePolicy::parse(policy_s)
            .with_context(|| format!("--policy {policy_s:?} (want ring or least-loaded)"))?;
        cfg.models = serve_model_specs(args)?;
        let shards = args.flag_usize("shards", 2)?.clamp(1, cfg.models.len());
        let single = loadgen::run_route(&cfg, policy, "route-1node", shards, 1, route_policy)?;
        let multi = loadgen::run_route(
            &cfg,
            policy,
            &format!("route-{nodes}nodes"),
            shards,
            nodes,
            route_policy,
        )?;
        let identical = loadgen::verify_route_bit_identity(&cfg, policy, shards, nodes)?;
        print!(
            "{}",
            report::serve_route(&single, &multi, nodes, shards, route_policy.label(), identical)
        );
        // One grep-able verdict line for CI.
        println!("route gate: bit identity {}", if identical { "PASS" } else { "FAIL" });
        let out = args.flag_str("out", "BENCH_route.json");
        let json = loadgen::route_bench_json(
            &cfg,
            shards,
            nodes,
            route_policy.label(),
            &single,
            &multi,
            identical,
        );
        std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
        if !identical {
            bail!("routed replay diverged from the unbatched oracle");
        }
        return Ok(());
    }

    // --cache-bytes: the content-addressed forward cache comparison.
    // Six legs — in-process, loopback HTTP, and flashwire, each run
    // once uncached and once with the given cache budget over the same
    // duplicate-heavy seeded workload — plus a serial bit-identity
    // replay of every cached transport against the unbatched oracle.
    // Writes BENCH_cache.json (DESIGN.md §16).
    if cache_mode {
        if cache_bytes == 0 {
            bail!("--cache-bytes 0 disables the cache; pass a positive byte budget to bench it");
        }
        if args.flag_bool("http") || args.flag_bool("wire") {
            bail!("--cache-bytes already runs in-process, HTTP, and wire legs; drop --http/--wire");
        }
        if autotune {
            bail!("--cache-bytes and --autotune are mutually exclusive (autotune uncached first)");
        }
        if args.flag("pipeline").is_some() {
            bail!("--cache-bytes benches the rational registry; --pipeline has no cached path yet");
        }
        if trace_out.is_some() {
            bail!("--trace-out and --cache-bytes are mutually exclusive (trace one leg instead)");
        }
        cfg.models = serve_model_specs(args)?;
        let shards = args.flag_usize("shards", 2)?.clamp(1, cfg.models.len());
        // Uncached legs pass budget 0 through the same entry points the
        // cached legs use, so the only difference between the paired
        // runs is the cache itself.
        let (in_u, _) =
            loadgen::run_sharded_cached(&cfg, policy, "in-process uncached", shards, 0)?;
        let (in_c, in_stats) =
            loadgen::run_sharded_cached(&cfg, policy, "in-process cached", shards, cache_bytes)?;
        let (http_u, _) =
            loadgen::run_http_cached(&cfg, policy, "loopback-http uncached", shards, 0)?;
        let (http_c, http_stats) =
            loadgen::run_http_cached(&cfg, policy, "loopback-http cached", shards, cache_bytes)?;
        let (wire_u, _) =
            loadgen::run_wire_cached(&cfg, policy, "loopback-wire uncached", shards, 0)?;
        let (wire_c, wire_stats) =
            loadgen::run_wire_cached(&cfg, policy, "loopback-wire cached", shards, cache_bytes)?;
        let identity = loadgen::verify_cached_bit_identity(&cfg, policy, shards, cache_bytes)?;
        let leg = |transport: &str, uncached, cached, stats| loadgen::CacheLeg {
            transport: transport.to_string(),
            uncached,
            cached,
            stats,
        };
        let legs = vec![
            leg("inproc", in_u, in_c, in_stats),
            leg("http", http_u, http_c, http_stats),
            leg("wire", wire_u, wire_c, wire_stats),
        ];
        print!("{}", report::serve_cache(&legs, &identity, shards, cache_bytes));
        // One grep-able verdict line for CI: the hit rate the in-process
        // cached leg measured, and the transport-wide identity gate.
        println!(
            "cache gate: hit rate {:.1}% (inproc), bit identity {}",
            100.0 * legs[0].hit_rate(),
            if identity.all_ok() { "PASS" } else { "FAIL" }
        );
        let out = args.flag_str("out", "BENCH_cache.json");
        let json = loadgen::cache_bench_json(&cfg, shards, cache_bytes, &legs, &identity);
        std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
        if !identity.all_ok() {
            bail!(
                "cached replay diverged from the unbatched oracle (inproc {}, http {}, wire {})",
                identity.inproc,
                identity.http,
                identity.wire
            );
        }
        return Ok(());
    }
    // --wire: the same workload in-process, over loopback HTTP/JSON,
    // and over the flashwire binary protocol — all three legs at the
    // same shard count — so the transport comparison in BENCH_wire.json
    // measures encodings and nothing else (DESIGN.md §13).
    if args.flag_bool("wire") {
        if args.flag_bool("http") {
            bail!("--wire already includes the HTTP/JSON leg; drop --http");
        }
        if args.flag("pipeline").is_some() {
            bail!("--wire benches the rational registry; use serve-wire --pipeline to serve one");
        }
        if autotune {
            bail!("--wire and --autotune are mutually exclusive (autotune in-process first)");
        }
        cfg.models = serve_model_specs(args)?;
        // Record the shard count the legs actually run on: the server
        // clamps to the registry size, and the published artifact must
        // not claim a sharding it never had.
        let shards = args.flag_usize("shards", 2)?.clamp(1, cfg.models.len());
        // With --trace-out every transport leg runs traced (one trace
        // file per leg), and one extra *untraced* in-process run pins
        // down the collector's throughput cost.
        let (inproc, http_res, wire_res, tracing) = if let Some(base) = trace_out {
            let t_in = Arc::new(TraceCollector::new());
            let inproc =
                loadgen::run_sharded_traced(&cfg, policy, "in-process", shards, t_in.clone())?;
            let t_http = Arc::new(TraceCollector::new());
            let http_res = loadgen::run_http_traced(
                &cfg,
                policy,
                "loopback-http",
                shards,
                Some(t_http.clone()),
            )?;
            let t_wire = Arc::new(TraceCollector::new());
            let wire_res = loadgen::run_wire_traced(
                &cfg,
                policy,
                "loopback-wire",
                shards,
                Some(t_wire.clone()),
            )?;
            let untraced = loadgen::run_sharded(&cfg, policy, "in-process-untraced", shards)?;
            let runs = vec![
                write_trace(&t_in, &trace_leg_path(base, "inproc"))?,
                write_trace(&t_http, &trace_leg_path(base, "http"))?,
                write_trace(&t_wire, &trace_leg_path(base, "wire"))?,
            ];
            let tj =
                loadgen::tracing_json(base, untraced.throughput_rps, inproc.throughput_rps, &runs);
            (inproc, http_res, wire_res, Some(tj))
        } else {
            (
                loadgen::run_sharded(&cfg, policy, "in-process", shards)?,
                loadgen::run_http(&cfg, policy, "loopback-http", shards)?,
                loadgen::run_wire(&cfg, policy, "loopback-wire", shards)?,
                None,
            )
        };
        let bytes = loadgen::transport_bytes(&cfg)?;
        print!("{}", report::serve_wire(&inproc, &http_res, &wire_res, shards, &bytes));
        let out = args.flag_str("out", "BENCH_wire.json");
        let mut json =
            loadgen::wire_bench_json(&cfg, &inproc, &http_res, &wire_res, shards, &bytes);
        if let Some(section) = tracing {
            push_tracing(&mut json, section);
        }
        std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
        return Ok(());
    }
    // --http: the same workload in-process and over loopback HTTP, so
    // the frontend's overhead is measured, not assumed (BENCH_http.json).
    if args.flag_bool("http") {
        if args.flag("pipeline").is_some() {
            bail!("--http benches the rational registry; use serve-http --pipeline to serve one");
        }
        if autotune {
            bail!("--http and --autotune are mutually exclusive (autotune in-process first)");
        }
        cfg.models = serve_model_specs(args)?;
        // Same shard count on both sides (clamped to the registry size,
        // as the server itself clamps), so the overhead numbers measure
        // the transport and nothing else — and the recorded shard count
        // is the one the legs actually ran on.
        let shards = args.flag_usize("shards", 2)?.clamp(1, cfg.models.len());
        let (inproc, http_res, tracing) = if let Some(base) = trace_out {
            let t_in = Arc::new(TraceCollector::new());
            let inproc =
                loadgen::run_sharded_traced(&cfg, policy, "in-process", shards, t_in.clone())?;
            let t_http = Arc::new(TraceCollector::new());
            let http_res = loadgen::run_http_traced(
                &cfg,
                policy,
                "loopback-http",
                shards,
                Some(t_http.clone()),
            )?;
            let untraced = loadgen::run_sharded(&cfg, policy, "in-process-untraced", shards)?;
            let runs = vec![
                write_trace(&t_in, &trace_leg_path(base, "inproc"))?,
                write_trace(&t_http, &trace_leg_path(base, "http"))?,
            ];
            let tj =
                loadgen::tracing_json(base, untraced.throughput_rps, inproc.throughput_rps, &runs);
            (inproc, http_res, Some(tj))
        } else {
            (
                loadgen::run_sharded(&cfg, policy, "in-process", shards)?,
                loadgen::run_http(&cfg, policy, "loopback-http", shards)?,
                None,
            )
        };
        print!("{}", report::serve_http(&inproc, &http_res, shards));
        let out = args.flag_str("out", "BENCH_http.json");
        let mut json = loadgen::http_bench_json(&cfg, &inproc, &http_res, shards);
        if let Some(section) = tracing {
            push_tracing(&mut json, section);
        }
        std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
        return Ok(());
    }
    // Repo rule: no silently-dead flags (--shards shards the transport
    // benches and the serving frontends; the in-process bench paths are
    // single-server).
    if args.flag("shards").is_some() {
        bail!(
            "--shards only applies with --http/--wire/--cache-bytes/--nodes (or the serve-http/serve-wire commands)"
        );
    }
    // Autotune sweep grid: the defaults plus any explicitly requested
    // policy point, so --max-batch / --deadline-us are folded into the
    // sweep instead of silently discarded.
    let mut tune_mbs = loadgen::AUTOTUNE_MAX_BATCH.to_vec();
    if args.flag("max-batch").is_some() {
        tune_mbs.push(max_batch);
    }
    tune_mbs.sort_unstable();
    tune_mbs.dedup();
    let mut tune_dls = loadgen::AUTOTUNE_DEADLINE_US.to_vec();
    if args.flag("deadline-us").is_some() {
        tune_dls.push(deadline_us);
    }
    tune_dls.sort_unstable();
    tune_dls.dedup();

    // Both serving modes reduce to "a way to build the registry"; the
    // orchestration (autotune sweep, or main run + max-batch-1 baseline)
    // is shared below instead of duplicated per mode.
    let (mut build, label_prefix): (
        Box<dyn FnMut() -> Result<Vec<Box<dyn ModelExecutor>>> + '_>,
        String,
    ) = if let Some(tag) = args.flag("pipeline") {
        use flashkat::serve::PipelineExecutor;
        // --pipeline serves <TAG>_eval end to end; the rational-registry
        // flags would be silently dead, so reject the combination (same
        // no-silent-override rule as cmd_report's --gpu/--b-sim).
        for f in ["model", "models", "d", "groups"] {
            if args.flag(f).is_some() {
                bail!("--{f} only applies to rational registries, not --pipeline");
            }
        }
        let rt = Runtime::cpu(args.flag_str("artifacts", "artifacts"))?;
        // Run <TAG>_init and compile <TAG>_eval once; every executor
        // instance (main run, baseline, autotune grid points) shares the
        // compilation and clones the parameter leaves.
        let init = rt.load(&format!("{tag}_init"))?;
        let params = init.execute(&[]).with_context(|| format!("running {tag}_init"))?;
        let eval = std::sync::Arc::new(rt.load(&format!("{tag}_eval"))?);
        let probe = PipelineExecutor::from_module(tag, eval.clone(), params.clone())?;
        cfg.models = vec![ModelSpec::new(tag, probe.d_in(), 1)];
        // The probe doubles as the first registry the builder hands out,
        // so its parameter serialization is not thrown away.
        let mut probe = Some(probe);
        let build = move || {
            let ex = match probe.take() {
                Some(ex) => ex,
                None => PipelineExecutor::from_module(tag, eval.clone(), params.clone())?,
            };
            Ok(vec![Box::new(ex) as Box<dyn ModelExecutor>])
        };
        (Box::new(build), format!("{tag} "))
    } else {
        cfg.models = serve_model_specs(args)?;
        (Box::new(|| loadgen::executors(&cfg)), String::new())
    };

    let json = if autotune {
        let res =
            loadgen::autotune_with(&cfg, policy, slo_p99_us, &tune_mbs, &tune_dls, &mut build)?;
        print!("{}", report::serve_autotune(&res));
        loadgen::autotune_json(&cfg, &res)
    } else {
        let main_res = loadgen::run_with(
            &cfg,
            build()?,
            policy,
            &format!("{label_prefix}max-batch {max_batch}"),
        )?;
        let baseline = if max_batch > 1 {
            Some(loadgen::run_with(
                &cfg,
                build()?,
                BatchPolicy { max_batch: 1, ..policy },
                &format!("{label_prefix}max-batch 1"),
            )?)
        } else {
            None
        };
        print!("{}", report::serve(&main_res, baseline.as_ref()));
        let mut json = loadgen::bench_json(&cfg, &main_res, baseline.as_ref());
        // One extra *traced* run of the main policy: the headline
        // numbers above stay untraced (comparable with past artifacts),
        // the trace file captures the same workload, and the rps pair
        // is the measured collector overhead.
        if let Some(path) = trace_out {
            let tracer = Arc::new(TraceCollector::new());
            let traced = loadgen::run_with_traced(
                &cfg,
                build()?,
                policy,
                &format!("{label_prefix}max-batch {max_batch} traced"),
                tracer.clone(),
            )?;
            let runs = vec![write_trace(&tracer, path)?];
            println!(
                "tracing overhead: {:.0} rps untraced vs {:.0} rps traced ({:.3}x)",
                main_res.throughput_rps,
                traced.throughput_rps,
                traced.throughput_rps / main_res.throughput_rps.max(1e-9),
            );
            push_tracing(
                &mut json,
                loadgen::tracing_json(path, main_res.throughput_rps, traced.throughput_rps, &runs),
            );
        }
        json
    };

    std::fs::write(out, json.to_string()).with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    Ok(())
}

/// The shared serving-frontend batch policy (`--max-batch`,
/// `--deadline-us`, `--queue-depth`, `--no-eager`).
fn serve_policy(args: &Args) -> Result<flashkat::serve::BatchPolicy> {
    Ok(flashkat::serve::BatchPolicy {
        max_batch: args.flag_usize("max-batch", 64)?.max(1),
        deadline_us: args.flag_u64("deadline-us", 200)?,
        queue_depth: args.flag_usize("queue-depth", 1024)?.max(1),
        eager: !args.flag_bool("no-eager"),
    })
}

/// Build the serving registry (`--models name:d[:groups],...` or
/// `--pipeline TAG`) and record the matching specs into `cfg` — shared
/// by the serve-http and serve-wire frontends so the two transports
/// serve byte-identical registries for the same flags.
fn serve_registry(
    args: &Args,
    cfg: &mut flashkat::serve::LoadConfig,
) -> Result<Vec<Box<dyn flashkat::serve::ModelExecutor>>> {
    use flashkat::serve::{loadgen, ModelExecutor, ModelSpec, PipelineExecutor};
    if let Some(tag) = args.flag("pipeline") {
        for f in ["model", "models", "d", "groups"] {
            if args.flag(f).is_some() {
                bail!("--{f} only applies to rational registries, not --pipeline");
            }
        }
        let rt = Runtime::cpu(args.flag_str("artifacts", "artifacts"))?;
        let ex = PipelineExecutor::from_runtime(&rt, tag)?;
        cfg.models = vec![ModelSpec::new(tag, ex.d_in(), 1)];
        Ok(vec![Box::new(ex)])
    } else {
        cfg.models = serve_model_specs(args)?;
        loadgen::executors(cfg)
    }
}

/// Run-until-signaled drain loop shared by both serving frontends:
/// block on the SIGTERM/SIGINT flag, drain, and print the final
/// counters (the "drained cleanly" line CI asserts on).
fn serve_until_signaled(
    shutdown: impl FnOnce() -> Option<flashkat::serve::ServeStats>,
) -> Result<()> {
    use flashkat::net::install_signal_handler;
    use std::sync::atomic::Ordering;

    let stop = install_signal_handler();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("signal received; draining in-flight requests...");
    let stats = shutdown().expect("first shutdown collects stats");
    let total = stats.total();
    println!(
        "drained cleanly: {} requests in {} batches ({} failed), peak queue {} across {} shards",
        total.requests,
        total.batches,
        total.failed,
        stats.peak_queued,
        stats.shard_peaks.len()
    );
    Ok(())
}

/// Stand up the HTTP/JSON serving frontend and run until SIGTERM/SIGINT,
/// then drain gracefully: `flashkat serve-http --addr A --port P
/// --shards N [--models ... | --pipeline TAG]`.  `--port 0` binds an
/// ephemeral port; the bound address is printed (and flushed) so
/// scripts can scrape it.  `--trace-out PATH` attaches a trace
/// collector for the server's lifetime and writes the Perfetto dump
/// after the drain completes.
fn cmd_serve_http(args: &Args) -> Result<()> {
    use flashkat::net::{HttpOptions, HttpServer, Limits};
    use flashkat::serve::{LoadConfig, Server};
    use std::io::Write as _;

    let host = args.flag_str("addr", "127.0.0.1");
    let port = args.flag_u16("port", 8080)?;
    let shards = args.flag_usize("shards", 2)?.max(1);
    let policy = serve_policy(args)?;
    let mut cfg = LoadConfig { seed: args.flag_u64("seed", 7)?, ..Default::default() };
    let executors = serve_registry(args, &mut cfg)?;
    let n_models = executors.len();
    let tracer = args
        .flag("trace-out")
        .map(|_| std::sync::Arc::new(flashkat::trace::TraceCollector::new()));
    // --cache-bytes N attaches the content-addressed forward cache
    // (DESIGN.md §16); 0 (the default) leaves it off and the submit
    // path byte-identical to previous releases.
    let cache_bytes = args.flag_usize("cache-bytes", 0)?;
    let server = std::sync::Arc::new(Server::start_configured(
        executors,
        policy,
        shards,
        tracer.clone(),
        cache_bytes,
    )?);
    let shards = server.shards(); // clamped to the registry size
    let opts = HttpOptions {
        conn_threads: args.flag_usize("conn-threads", 8)?.max(1),
        backlog: args.flag_usize("backlog", 64)?.max(1),
        limits: Limits {
            max_body_bytes: args.flag_usize("max-body-bytes", 8 * 1024 * 1024)?.max(1),
            ..Default::default()
        },
    };
    let http = HttpServer::bind(&format!("{host}:{port}"), server, opts)?;
    println!(
        "listening on http://{} ({n_models} models, {shards} shards, seed {})",
        http.local_addr(),
        cfg.seed
    );
    println!("routes: POST /v1/models/<name>/infer | GET /v1/models /healthz /metrics");
    // The bound-port line is scraped by scripts (CI starts us with
    // --port 0); a piped stdout is block-buffered, so flush explicitly.
    std::io::stdout().flush().ok();
    serve_until_signaled(|| http.shutdown())?;
    if let (Some(t), Some(path)) = (&tracer, args.flag("trace-out")) {
        write_trace(t, path)?;
    }
    Ok(())
}

/// Stand up the flashwire binary serving frontend (DESIGN.md §13) and
/// run until SIGTERM/SIGINT, then drain gracefully: `flashkat
/// serve-wire --addr A --port P --shards N [--models ... | --pipeline
/// TAG]`.  Same registry, policy, drain, and `--trace-out` semantics
/// as serve-http — only the bytes on the socket differ.
fn cmd_serve_wire(args: &Args) -> Result<()> {
    use flashkat::serve::{LoadConfig, Server};
    use flashkat::wire::{WireLimits, WireOptions, WireServer};
    use std::io::Write as _;

    let host = args.flag_str("addr", "127.0.0.1");
    let port = args.flag_u16("port", 8081)?;
    let shards = args.flag_usize("shards", 2)?.max(1);
    let policy = serve_policy(args)?;
    let mut cfg = LoadConfig { seed: args.flag_u64("seed", 7)?, ..Default::default() };
    let executors = serve_registry(args, &mut cfg)?;
    let n_models = executors.len();
    let tracer = args
        .flag("trace-out")
        .map(|_| std::sync::Arc::new(flashkat::trace::TraceCollector::new()));
    // Same cache semantics as serve-http: 0 (default) = off.
    let cache_bytes = args.flag_usize("cache-bytes", 0)?;
    let server = std::sync::Arc::new(Server::start_configured(
        executors,
        policy,
        shards,
        tracer.clone(),
        cache_bytes,
    )?);
    let shards = server.shards(); // clamped to the registry size
    let opts = WireOptions {
        conn_threads: args.flag_usize("conn-threads", 8)?.max(1),
        backlog: args.flag_usize("backlog", 64)?.max(1),
        limits: WireLimits {
            max_payload_bytes: args.flag_usize("max-payload-bytes", 8 * 1024 * 1024)?.max(1),
            ..Default::default()
        },
    };
    let wire = WireServer::bind(&format!("{host}:{port}"), server, opts)?;
    println!(
        "listening on flashwire://{} ({n_models} models, {shards} shards, seed {})",
        wire.local_addr(),
        cfg.seed
    );
    println!(
        "frames: InferRequest/InferResponse, StatsRequest/StatsResponse, Ping/Pong (DESIGN.md \u{a7}13)"
    );
    std::io::stdout().flush().ok();
    serve_until_signaled(|| wire.shutdown())?;
    if let (Some(t), Some(path)) = (&tracer, args.flag("trace-out")) {
        write_trace(t, path)?;
    }
    Ok(())
}

/// Stand up the flashroute multi-node router tier (DESIGN.md §18) and
/// run until SIGTERM/SIGINT: `flashkat route --port P --backends
/// HOST:PORT,HOST:PORT,...`.  ONE front port accepts both flashwire and
/// HTTP clients (each connection is protocol-sniffed on its first two
/// bytes) and fans requests out across the backend serve-wire processes:
/// consistent-hash routing by model name (`--policy least-loaded` ranks
/// the failover order by live backend load instead), Ping-probed health
/// circuits with half-open recovery, and shed-aware failover that honors
/// the backends' typed queue-full/draining retry hints.
fn cmd_route(args: &Args) -> Result<()> {
    use flashkat::route::{RouteOptions, RoutePolicy, RouteServer};
    use flashkat::wire::WireLimits;
    use std::io::Write as _;
    use std::net::ToSocketAddrs as _;
    use std::sync::atomic::Ordering;

    let host = args.flag_str("addr", "127.0.0.1");
    let port = args.flag_u16("port", 8082)?;
    let raw = args
        .flag("backends")
        .context("route needs --backends HOST:PORT[,HOST:PORT,...]")?;
    let mut backends = Vec::new();
    for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let addr = tok
            .to_socket_addrs()
            .with_context(|| format!("resolving backend {tok:?}"))?
            .next()
            .with_context(|| format!("backend {tok:?} resolved to no address"))?;
        backends.push(addr);
    }
    if backends.is_empty() {
        bail!("--backends lists no addresses");
    }
    let policy_s = args.flag_str("policy", "ring");
    let policy = RoutePolicy::parse(policy_s)
        .with_context(|| format!("--policy {policy_s:?} (want ring or least-loaded)"))?;
    let tracer = args
        .flag("trace-out")
        .map(|_| std::sync::Arc::new(flashkat::trace::TraceCollector::new()));
    let opts = RouteOptions {
        conn_threads: args.flag_usize("conn-threads", 8)?.max(1),
        backlog: args.flag_usize("backlog", 64)?.max(1),
        limits: WireLimits {
            max_payload_bytes: args.flag_usize("max-payload-bytes", 8 * 1024 * 1024)?.max(1),
            ..Default::default()
        },
        policy,
        probe_interval: std::time::Duration::from_millis(
            args.flag_u64("probe-interval-ms", 200)?.max(1),
        ),
        fail_threshold: args.flag_u32("fail-threshold", 3)?.max(1),
        down_cooldown: args.flag_u32("down-cooldown", 2)?.max(1),
        tracer: tracer.clone(),
    };
    let n = backends.len();
    let router = RouteServer::bind(&format!("{host}:{port}"), backends, opts)?;
    println!(
        "listening on flashwire://{} ({n} backends, policy {})",
        router.local_addr(),
        policy.label()
    );
    println!(
        "same port speaks HTTP: POST /v1/models/<name>/infer, GET /healthz /metrics (flashkat_route_*)"
    );
    // The bound-port line is scraped by scripts (CI starts us with
    // --port 0); a piped stdout is block-buffered, so flush explicitly.
    std::io::stdout().flush().ok();
    let stop = flashkat::net::install_signal_handler();
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("signal received; draining in-flight requests...");
    let stats = router.shutdown().expect("first shutdown collects stats");
    println!(
        "drained cleanly: {} replies forwarded, {} failovers ({} transport failures) across {} backends",
        stats.forwarded, stats.retried, stats.failed, stats.backends
    );
    if let (Some(t), Some(path)) = (&tracer, args.flag("trace-out")) {
        write_trace(t, path)?;
    }
    Ok(())
}

/// Sanity-scan a Perfetto trace written by `--trace-out`: `flashkat
/// trace-stat PATH`.  Walks the packet stream with the same varint/field
/// decoder the renderer is tested against, prints the counts, and fails
/// (exit 1) on an empty or slice-unbalanced trace — the machine-checkable
/// "this trace will load in ui.perfetto.dev" assertion CI runs.
fn cmd_trace_stat(args: &Args) -> Result<()> {
    use flashkat::util::json::Json;

    // The flag grammar greedily binds a following bare token to the
    // flag, so `trace-stat --json PATH` parses as `json=PATH` with no
    // positional; reclaim that value as the path.  `PATH --json` and
    // `--json=true PATH` hit the ordinary cases.
    let (as_json, path) = match (args.flag("json"), args.positional.first()) {
        (Some(_), Some(p)) => (true, p.clone()),
        (Some(v), None) if v != "true" => (true, v.to_string()),
        (None, Some(p)) => (false, p.clone()),
        _ => bail!("usage: flashkat trace-stat [--json] PATH"),
    };
    let bytes = std::fs::read(&path).with_context(|| format!("reading {path}"))?;
    let stat = flashkat::trace::stat(&bytes).map_err(|e| anyhow!("{path}: {e}"))?;
    let tracks = flashkat::trace::stat_by_track(&bytes).map_err(|e| anyhow!("{path}: {e}"))?;
    if as_json {
        let json = Json::Obj(vec![
            ("path".to_string(), Json::Str(path.clone())),
            ("bytes".to_string(), Json::Int(bytes.len() as i64)),
            ("packets".to_string(), Json::Int(stat.packets as i64)),
            ("track_descriptors".to_string(), Json::Int(stat.track_descriptors as i64)),
            ("slice_begins".to_string(), Json::Int(stat.slice_begins as i64)),
            ("slice_ends".to_string(), Json::Int(stat.slice_ends as i64)),
            ("instants".to_string(), Json::Int(stat.instants as i64)),
            ("counters".to_string(), Json::Int(stat.counters as i64)),
            (
                "tracks".to_string(),
                Json::Arr(
                    tracks
                        .iter()
                        .map(|(name, events)| {
                            Json::Obj(vec![
                                ("name".to_string(), Json::Str(name.clone())),
                                ("events".to_string(), Json::Int(*events as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        println!("{}", json.to_string());
    } else {
        println!(
            "{path}: {} packets ({} track descriptors, {} slice begins, {} slice ends, {} instants, {} counters) in {} bytes",
            stat.packets,
            stat.track_descriptors,
            stat.slice_begins,
            stat.slice_ends,
            stat.instants,
            stat.counters,
            bytes.len()
        );
        for (name, events) in &tracks {
            println!("  track {name:?}: {events} events");
        }
    }
    if stat.packets == 0 {
        bail!("{path}: empty trace (0 packets)");
    }
    if stat.slice_begins != stat.slice_ends {
        bail!(
            "{path}: unbalanced slices ({} begins vs {} ends)",
            stat.slice_begins,
            stat.slice_ends
        );
    }
    Ok(())
}

/// Runtime integration check: run the standalone rational kernels through
/// PJRT and compare against the Rust-side oracle.
fn cmd_selfcheck(args: &Args) -> Result<()> {
    use flashkat::rational::accumulate::{backward, Strategy};
    use flashkat::rational::Coeffs;
    use flashkat::runtime::HostTensor;
    use flashkat::util::rng::Pcg64;

    let artifacts = args.flag_str("artifacts", "artifacts");
    let rt = Runtime::cpu(artifacts)?;
    println!("platform: {}", rt.platform());

    let m = rt.load("rational_fwd")?;
    let dims: Vec<usize> = m
        .manifest
        .raw
        .get("dims")
        .and_then(|d| d.as_arr())
        .context("dims meta")?
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let (b, n, d) = (dims[0], dims[1], dims[2]);
    let rows = b * n;
    let mut rng = Pcg64::new(7);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);

    let inputs = [
        HostTensor::F32 { shape: vec![b, n, d], data: x.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: coeffs.a.clone() },
        HostTensor::F32 { shape: vec![8, 4], data: coeffs.b.clone() },
    ];
    let outs = m.execute(&inputs)?;
    let got = outs[0].as_f32()?;
    let want = flashkat::rational::forward(&x, rows, d, &coeffs);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    println!(
        "rational_fwd: max |pallas - rust oracle| = {max_err:.3e} over {} elements",
        got.len()
    );
    if max_err > 1e-3 {
        bail!("forward mismatch");
    }

    let mb = rt.load("rational_bwd_flash")?;
    let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let inputs = [
        HostTensor::F32 { shape: vec![b, n, d], data: x.clone() },
        HostTensor::F32 { shape: vec![b, n, d], data: dout.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: coeffs.a.clone() },
        HostTensor::F32 { shape: vec![8, 4], data: coeffs.b.clone() },
    ];
    let outs = mb.execute(&inputs)?;
    let (_, da_r, db_r) =
        backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block: 128 });
    let da = outs[1].as_f32()?;
    let db = outs[2].as_f32()?;
    let scale = da_r.iter().map(|v| v.abs() as f64).fold(1.0, f64::max);
    let err_a =
        da.iter().zip(&da_r).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max) / scale;
    let scale_b = db_r.iter().map(|v| v.abs() as f64).fold(1.0, f64::max);
    let err_b =
        db.iter().zip(&db_r).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max) / scale_b;
    println!("rational_bwd_flash: rel dA err {err_a:.3e}, rel dB err {err_b:.3e}");
    if err_a > 1e-3 || err_b > 1e-3 {
        bail!("backward mismatch");
    }
    println!("selfcheck OK");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "report" => cmd_report(&args),
        "train" => cmd_train(&args),
        "profile" => cmd_profile(&args),
        "profile-kernel" => cmd_profile_kernel(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "serve-http" => cmd_serve_http(&args),
        "serve-wire" => cmd_serve_wire(&args),
        "route" => cmd_route(&args),
        "trace-stat" => cmd_trace_stat(&args),
        "selfcheck" => cmd_selfcheck(&args),
        "flops" => {
            print!("{}", report::table1());
            Ok(())
        }
        "" | "help" | "--help" => {
            println!(
                "flashkat — FlashKAT reproduction (see DESIGN.md)\n\n\
                 usage: flashkat <report|train|profile|profile-kernel|serve-bench|serve-http|serve-wire|route|trace-stat|selfcheck|flops> [flags]\n\
                 \x20 report <fig1|table1|table2|fig2|fig3|table3|table4|table5|configs|all>\n\
                 \x20 train  [--model kat_micro|vit_micro|kat_micro_katbwd] [--steps N] [--ckpt PATH]\n\
                 \x20 profile [--kernel fwd|kat|flash] [--loops N] [--gpu 4060ti|h200]\n\
                 \x20 profile-kernel [--rows N] [--d N] [--groups N] [--s-block N] [--iters N]\n\
                 \x20             [--seed N] [--gpu 4060ti|h200] [--out PATH]\n\
                 \x20             (host-kernel roofline: bit-identity gate, per-phase measured\n\
                 \x20              bytes/element vs the gpusim prediction; needs --features probe;\n\
                 \x20              writes BENCH_profile.json)\n\
                 \x20 serve-bench [--requests N] [--concurrency C] [--max-batch B] [--deadline-us D]\n\
                 \x20             [--queue-depth N] [--no-eager] [--open-loop --rate RPS]\n\
                 \x20             [--model NAME] [--models name:d[:groups],...] [--d N] [--groups N]\n\
                 \x20             [--pipeline TAG [--artifacts DIR]]  (serve a whole <TAG>_eval model)\n\
                 \x20             [--autotune [--slo-p99-us N]]  (sweep max-batch/deadline vs the SLO)\n\
                 \x20             [--http [--shards N]]  (also run over loopback HTTP; writes BENCH_http.json)\n\
                 \x20             [--wire [--shards N]]  (in-process vs HTTP/JSON vs flashwire binary;\n\
                 \x20              writes BENCH_wire.json with bytes-per-request)\n\
                 \x20             [--cache-bytes N [--shards N]]  (content-addressed forward cache:\n\
                 \x20              cached-vs-uncached legs over all three transports on a duplicate-\n\
                 \x20              heavy workload + bit-identity gate; writes BENCH_cache.json)\n\
                 \x20             [--dup-frac F]  (fraction of requests replaying a prior request's\n\
                 \x20              exact bytes; defaults 0.5 with --cache-bytes, else 0)\n\
                 \x20             [--nodes N [--shards N] [--policy ring|least-loaded]]  (flashroute\n\
                 \x20              scaling: 1-node vs N-node tier through the router, bit-identity\n\
                 \x20              gate; writes BENCH_route.json with the efficiency block)\n\
                 \x20             [--seed N] [--out PATH] [--trace-out PATH]\n\
                 \x20             [--profile]  (print kernel traffic-probe totals after the run;\n\
                 \x20              needs a build with --features probe)\n\
                 \x20             (micro-batching inference bench; writes BENCH_serve.json;\n\
                 \x20              --trace-out also runs a traced leg per transport and writes\n\
                 \x20              Perfetto traces next to the bench JSON)\n\
                 \x20 serve-http [--addr A] [--port P|0] [--shards N] [--conn-threads N]\n\
                 \x20             [--models name:d[:groups],... | --pipeline TAG] [--max-batch B]\n\
                 \x20             [--cache-bytes N]  (content-addressed result cache; 0 = off)\n\
                 \x20             [--deadline-us D] [--queue-depth N] [--max-body-bytes N] [--seed N]\n\
                 \x20             [--trace-out PATH]  (write a Perfetto trace on drain)\n\
                 \x20             (HTTP/JSON frontend; POST /v1/models/<name>/infer, GET /v1/models\n\
                 \x20              /healthz /metrics; runs until SIGTERM, then drains)\n\
                 \x20 serve-wire [--addr A] [--port P|0] [--shards N] [--conn-threads N]\n\
                 \x20             [--models name:d[:groups],... | --pipeline TAG] [--max-batch B]\n\
                 \x20             [--cache-bytes N]  (content-addressed result cache; 0 = off)\n\
                 \x20             [--deadline-us D] [--queue-depth N] [--max-payload-bytes N] [--seed N]\n\
                 \x20             [--trace-out PATH]  (write a Perfetto trace on drain)\n\
                 \x20             (flashwire length-prefixed binary frontend, DESIGN.md \u{a7}13;\n\
                 \x20              runs until SIGTERM, then drains)\n\
                 \x20 route      --backends HOST:PORT,... [--addr A] [--port P|0]\n\
                 \x20             [--policy ring|least-loaded] [--conn-threads N] [--backlog N]\n\
                 \x20             [--probe-interval-ms N] [--fail-threshold N] [--down-cooldown N]\n\
                 \x20             [--max-payload-bytes N] [--trace-out PATH]\n\
                 \x20             (flashroute multi-node tier, DESIGN.md \u{a7}18: one front port for\n\
                 \x20              wire AND http clients, consistent-hash fan-out over serve-wire\n\
                 \x20              backends, Ping-probed health failover; runs until SIGTERM)\n\
                 \x20 trace-stat [--json] PATH   -- scan a Perfetto trace written by --trace-out\n\
                 \x20             and print packet/slice/counter counts plus per-track event\n\
                 \x20             counts (non-empty + balanced, else exit 1; --json emits one\n\
                 \x20             machine-readable object)\n\
                 \x20 selfcheck [--artifacts DIR]"
            );
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `flashkat help`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn serve_model_specs_parses_registries() {
        let specs = serve_model_specs(&parse("serve-http --models wide:256:8,narrow:64")).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!((specs[0].name.as_str(), specs[0].d, specs[0].n_groups), ("wide", 256, 8));
        assert_eq!((specs[1].name.as_str(), specs[1].d, specs[1].n_groups), ("narrow", 64, 8));
        let single = serve_model_specs(&parse("serve-http --model m --d 128")).unwrap();
        assert_eq!((single[0].name.as_str(), single[0].d), ("m", 128));
    }

    /// Models route by name, so `--models a:64,a:128` can only mean one
    /// entry silently shadowing the other — reject it at the CLI with
    /// both entries named, before any server is built.
    #[test]
    fn serve_model_specs_rejects_duplicate_names() {
        let err = serve_model_specs(&parse("serve-http --models a:64,b:32,a:128"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"a\" twice"), "{err}");
        assert!(err.contains("a:64") && err.contains("a:128"), "names both widths: {err}");
        // Same name, same width: still a duplicate route.
        assert!(serve_model_specs(&parse("serve-http --models a:64,a:64")).is_err());
        // Distinct names stay fine.
        assert!(serve_model_specs(&parse("serve-http --models a:64,b:64")).is_ok());
    }

    #[test]
    fn serve_model_specs_rejects_conflicting_flag_combos() {
        assert!(serve_model_specs(&parse("serve-http --models a:64 --model b")).is_err());
        assert!(serve_model_specs(&parse("serve-http --models a:64 --d 32")).is_err());
        assert!(serve_model_specs(&parse("serve-http --models ,,")).is_err(), "empty list");
        assert!(serve_model_specs(&parse("serve-http --models a:sixty")).is_err(), "bad width");
    }
}
