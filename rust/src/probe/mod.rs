//! Kernel memory-traffic probes (DESIGN.md §17).
//!
//! FlashKAT's diagnosis ran on *traffic*, not FLOPs: the KAT backward
//! was 123x slower than its FLOP-equivalent MLP because of memory
//! stalls that only showed up once bytes moved per kernel phase were
//! measured.  This module gives the host kernels the same instrument:
//! per-thread counters of bytes loaded/stored per logical stream and
//! kernel phase, plus structural events (accumulator run-flushes,
//! spill-path falls, SIMD masked-tail lanes).
//!
//! Everything is behind the `probe` cargo feature.  With the feature
//! off, every `on_*` function below is an empty `#[inline(always)]`
//! no-op — the call sites in `rational/` compile to nothing, so the
//! default build's kernels are byte-for-byte the unprobed kernels.
//! With the feature on, counting touches only thread-local relaxed
//! atomics, never the float data, so kernel outputs stay bit-identical
//! (gated in `tests/kernel_parity.rs`).
//!
//! Counters are process-global: each worker thread lazily registers an
//! atomic counter block in a global registry on first probe hit, and
//! [`snapshot`] sums across all of them.  `cargo test` runs tests
//! concurrently in one process, so tests assert monotonic deltas, not
//! absolute values.

use std::fmt;

/// Kernel phase a byte of traffic is attributed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `rational::forward_into` segment evaluation.
    Forward,
    /// Fused backward tile pass (dx + per-tile dA/dB partials).
    Backward,
    /// Cross-tile partial reduction into the final dA/dB rows.
    Reduce,
}

impl Phase {
    pub const COUNT: usize = 3;
    pub const ALL: [Phase; Phase::COUNT] = [Phase::Forward, Phase::Backward, Phase::Reduce];

    pub fn index(self) -> usize {
        match self {
            Phase::Forward => 0,
            Phase::Backward => 1,
            Phase::Reduce => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::Backward => "backward",
            Phase::Reduce => "reduce",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Logical data stream a byte of traffic belongs to.  "Bytes" means
/// the payload the kernel logically touches at each access site
/// (`len * size_of::<T>()`), counted once per touch — the host analogue
/// of the per-warp load/store bytes `gpusim::kernels` budgets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    /// Input activations.
    X,
    /// Upstream gradient.
    Dout,
    /// Rational coefficient rows (a, b).
    Coeffs,
    /// Forward output.
    Y,
    /// Input gradient.
    Dx,
    /// dA/dB accumulator partials (tile-local and cross-tile).
    Partials,
}

impl Stream {
    pub const COUNT: usize = 6;
    pub const ALL: [Stream; Stream::COUNT] =
        [Stream::X, Stream::Dout, Stream::Coeffs, Stream::Y, Stream::Dx, Stream::Partials];

    pub fn index(self) -> usize {
        match self {
            Stream::X => 0,
            Stream::Dout => 1,
            Stream::Coeffs => 2,
            Stream::Y => 3,
            Stream::Dx => 4,
            Stream::Partials => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stream::X => "x",
            Stream::Dout => "dout",
            Stream::Coeffs => "coeffs",
            Stream::Y => "y",
            Stream::Dx => "dx",
            Stream::Partials => "partials",
        }
    }
}

impl fmt::Display for Stream {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Point-in-time sum of every thread's counters.  With the `probe`
/// feature off this is always [`Snapshot::default`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    /// Bytes loaded, `[phase][stream]`.
    pub loads: [[u64; Stream::COUNT]; Phase::COUNT],
    /// Bytes stored, `[phase][stream]`.
    pub stores: [[u64; Stream::COUNT]; Phase::COUNT],
    /// TileAcc / SpillAcc / SIMD accumulator run flushes.
    pub run_flushes: u64,
    /// Times `SpillAcc` was constructed (coefficient widths beyond the
    /// register-resident tile fell back to the heap twin).
    pub spill_falls: u64,
    /// Dead SIMD lanes across all masked-tail segment iterations.
    pub masked_tail_lanes: u64,
    /// Threads that have recorded at least one probe event.
    pub threads: usize,
}

impl Snapshot {
    /// Whether the binary was built with probes compiled in.
    pub fn enabled() -> bool {
        cfg!(feature = "probe")
    }

    pub fn loaded(&self, p: Phase, s: Stream) -> u64 {
        self.loads[p.index()][s.index()]
    }

    pub fn stored(&self, p: Phase, s: Stream) -> u64 {
        self.stores[p.index()][s.index()]
    }

    /// Total bytes (loads + stores) attributed to one phase.
    pub fn phase_bytes(&self, p: Phase) -> u64 {
        let i = p.index();
        self.loads[i].iter().chain(self.stores[i].iter()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        Phase::ALL.iter().map(|&p| self.phase_bytes(p)).sum()
    }

    /// Element-wise `self - base` (saturating): the traffic recorded
    /// between two snapshots.
    pub fn delta_since(&self, base: &Snapshot) -> Snapshot {
        let mut d = self.clone();
        for p in 0..Phase::COUNT {
            for s in 0..Stream::COUNT {
                d.loads[p][s] = d.loads[p][s].saturating_sub(base.loads[p][s]);
                d.stores[p][s] = d.stores[p][s].saturating_sub(base.stores[p][s]);
            }
        }
        d.run_flushes = d.run_flushes.saturating_sub(base.run_flushes);
        d.spill_falls = d.spill_falls.saturating_sub(base.spill_falls);
        d.masked_tail_lanes = d.masked_tail_lanes.saturating_sub(base.masked_tail_lanes);
        d
    }
}

// ---------------------------------------------------------------------------
// probes ON: thread-local relaxed atomics, lazily registered globally.
// ---------------------------------------------------------------------------

#[cfg(feature = "probe")]
mod imp {
    use super::{Phase, Snapshot, Stream};
    use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
    use std::sync::{Arc, Mutex, OnceLock};

    // `const` item so array repeat is allowed for a non-Copy type.
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);

    pub struct ThreadCounters {
        pub loads: [[AtomicU64; Stream::COUNT]; Phase::COUNT],
        pub stores: [[AtomicU64; Stream::COUNT]; Phase::COUNT],
        pub run_flushes: AtomicU64,
        pub spill_falls: AtomicU64,
        pub masked_tail_lanes: AtomicU64,
    }

    impl ThreadCounters {
        fn new() -> Self {
            Self {
                loads: [[ZERO; Stream::COUNT]; Phase::COUNT],
                stores: [[ZERO; Stream::COUNT]; Phase::COUNT],
                run_flushes: ZERO,
                spill_falls: ZERO,
                masked_tail_lanes: ZERO,
            }
        }
    }

    fn registry() -> &'static Mutex<Vec<Arc<ThreadCounters>>> {
        static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadCounters>>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
    }

    thread_local! {
        static LOCAL: Arc<ThreadCounters> = {
            let c = Arc::new(ThreadCounters::new());
            registry().lock().expect("probe registry poisoned").push(c.clone());
            c
        };
    }

    #[inline]
    pub fn with_local<R>(f: impl FnOnce(&ThreadCounters) -> R) -> R {
        LOCAL.with(|c| f(c))
    }

    pub fn snapshot() -> Snapshot {
        let reg = registry().lock().expect("probe registry poisoned");
        let mut snap = Snapshot { threads: reg.len(), ..Snapshot::default() };
        for t in reg.iter() {
            for p in 0..Phase::COUNT {
                for s in 0..Stream::COUNT {
                    snap.loads[p][s] += t.loads[p][s].load(Relaxed);
                    snap.stores[p][s] += t.stores[p][s].load(Relaxed);
                }
            }
            snap.run_flushes += t.run_flushes.load(Relaxed);
            snap.spill_falls += t.spill_falls.load(Relaxed);
            snap.masked_tail_lanes += t.masked_tail_lanes.load(Relaxed);
        }
        snap
    }

    pub fn reset() {
        let reg = registry().lock().expect("probe registry poisoned");
        for t in reg.iter() {
            for p in 0..Phase::COUNT {
                for s in 0..Stream::COUNT {
                    t.loads[p][s].store(0, Relaxed);
                    t.stores[p][s].store(0, Relaxed);
                }
            }
            t.run_flushes.store(0, Relaxed);
            t.spill_falls.store(0, Relaxed);
            t.masked_tail_lanes.store(0, Relaxed);
        }
    }
}

#[cfg(feature = "probe")]
#[inline]
pub fn on_load(phase: Phase, stream: Stream, bytes: u64) {
    use std::sync::atomic::Ordering::Relaxed;
    imp::with_local(|c| c.loads[phase.index()][stream.index()].fetch_add(bytes, Relaxed));
}

#[cfg(feature = "probe")]
#[inline]
pub fn on_store(phase: Phase, stream: Stream, bytes: u64) {
    use std::sync::atomic::Ordering::Relaxed;
    imp::with_local(|c| c.stores[phase.index()][stream.index()].fetch_add(bytes, Relaxed));
}

#[cfg(feature = "probe")]
#[inline]
pub fn on_run_flush() {
    use std::sync::atomic::Ordering::Relaxed;
    imp::with_local(|c| c.run_flushes.fetch_add(1, Relaxed));
}

#[cfg(feature = "probe")]
#[inline]
pub fn on_spill_fall() {
    use std::sync::atomic::Ordering::Relaxed;
    imp::with_local(|c| c.spill_falls.fetch_add(1, Relaxed));
}

#[cfg(feature = "probe")]
#[inline]
pub fn on_masked_tail(lanes: u64) {
    use std::sync::atomic::Ordering::Relaxed;
    imp::with_local(|c| c.masked_tail_lanes.fetch_add(lanes, Relaxed));
}

/// Sum every registered thread's counters.
#[cfg(feature = "probe")]
pub fn snapshot() -> Snapshot {
    imp::snapshot()
}

/// Zero every registered thread's counters.  Other threads may be
/// recording concurrently; use snapshot deltas when that matters.
#[cfg(feature = "probe")]
pub fn reset() {
    imp::reset()
}

// ---------------------------------------------------------------------------
// probes OFF: every hook is an empty inlined no-op.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "probe"))]
#[inline(always)]
pub fn on_load(_phase: Phase, _stream: Stream, _bytes: u64) {}

#[cfg(not(feature = "probe"))]
#[inline(always)]
pub fn on_store(_phase: Phase, _stream: Stream, _bytes: u64) {}

#[cfg(not(feature = "probe"))]
#[inline(always)]
pub fn on_run_flush() {}

#[cfg(not(feature = "probe"))]
#[inline(always)]
pub fn on_spill_fall() {}

#[cfg(not(feature = "probe"))]
#[inline(always)]
pub fn on_masked_tail(_lanes: u64) {}

#[cfg(not(feature = "probe"))]
pub fn snapshot() -> Snapshot {
    Snapshot::default()
}

#[cfg(not(feature = "probe"))]
pub fn reset() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_default_is_zero() {
        let s = Snapshot::default();
        assert_eq!(s.total_bytes(), 0);
        assert_eq!(s.phase_bytes(Phase::Forward), 0);
        assert_eq!(s.delta_since(&Snapshot::default()), Snapshot::default());
    }

    #[test]
    fn phase_and_stream_indices_cover_all() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
            assert!(!p.name().is_empty());
        }
        for (i, s) in Stream::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert!(!s.name().is_empty());
        }
    }

    #[cfg(feature = "probe")]
    #[test]
    fn counters_accumulate_and_delta() {
        // Other tests may be recording concurrently on other threads,
        // so assert monotone growth of this thread's contribution only.
        let base = snapshot();
        on_load(Phase::Forward, Stream::X, 128);
        on_store(Phase::Forward, Stream::Y, 64);
        on_run_flush();
        on_masked_tail(3);
        let d = snapshot().delta_since(&base);
        assert!(d.loaded(Phase::Forward, Stream::X) >= 128);
        assert!(d.stored(Phase::Forward, Stream::Y) >= 64);
        assert!(d.run_flushes >= 1);
        assert!(d.masked_tail_lanes >= 3);
        assert!(snapshot().threads >= 1);
    }

    #[cfg(not(feature = "probe"))]
    #[test]
    fn probes_off_compile_to_nothing() {
        on_load(Phase::Forward, Stream::X, 128);
        on_store(Phase::Backward, Stream::Dx, 64);
        on_run_flush();
        on_spill_fall();
        on_masked_tail(7);
        assert_eq!(snapshot(), Snapshot::default());
        assert!(!Snapshot::enabled());
    }
}
