//! Configuration system: model variants (paper Table 6), training
//! hyperparameters (paper Table 7), and JSON (de)serialization so runs are
//! reproducible from config files.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Model architecture variant (paper Table 6 + CPU-scale micro).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub img_size: usize,
    pub patch: usize,
    pub in_ch: usize,
    pub d: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
    pub n_classes: usize,
    /// "grkan" (KAT) or "mlp" (ViT/DeiT).
    pub ffn: String,
    pub n_groups: usize,
    /// "flash" (Algorithm 2) or "kat" (Algorithm 1).
    pub backward: String,
    pub drop_path: f64,
}

impl ModelConfig {
    pub fn preset(name: &str) -> Result<Self> {
        let base = Self {
            name: name.to_string(),
            img_size: 224,
            patch: 16,
            in_ch: 3,
            d: 192,
            depth: 12,
            heads: 3,
            mlp_ratio: 4,
            n_classes: 1000,
            ffn: "grkan".into(),
            n_groups: 8,
            backward: "flash".into(),
            drop_path: 0.1,
        };
        Ok(match name {
            "kat-t" => base,
            "kat-s" => Self { d: 384, heads: 6, ..base },
            "kat-b" => Self { d: 768, heads: 12, drop_path: 0.4, ..base },
            "vit-t" => Self { ffn: "mlp".into(), ..base },
            "vit-s" => Self { d: 384, heads: 6, ffn: "mlp".into(), ..base },
            "vit-b" => Self { d: 768, heads: 12, ffn: "mlp".into(), ..base },
            "kat-micro" => Self {
                img_size: 32,
                patch: 4,
                d: 128,
                depth: 4,
                heads: 4,
                n_classes: 10,
                drop_path: 0.05,
                ..base
            },
            "vit-micro" => Self {
                img_size: 32,
                patch: 4,
                d: 128,
                depth: 4,
                heads: 4,
                n_classes: 10,
                ffn: "mlp".into(),
                drop_path: 0.05,
                ..base
            },
            other => return Err(anyhow!("unknown model preset {other:?}")),
        })
    }

    pub fn n_patches(&self) -> usize {
        (self.img_size / self.patch).pow(2)
    }

    pub fn n_tokens(&self) -> usize {
        self.n_patches() + 1
    }

    /// Analytic parameter count (mirrors python `count_params_analytic`).
    pub fn param_count(&self) -> usize {
        let (d, dh) = (self.d, self.d * self.mlp_ratio);
        let patch = (self.patch * self.patch * self.in_ch + 1) * d;
        let embed = d + self.n_tokens() * d;
        let attn = 4 * d * d + 4 * d;
        let ln = 2 * d;
        let mut ffn = d * dh + dh + dh * d + d;
        if self.ffn == "grkan" {
            ffn += 2 * self.n_groups * 10;
        }
        let block = ln + attn + ln + ffn;
        let head = d * self.n_classes + self.n_classes;
        patch + embed + self.depth * block + ln + head
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("img_size".into(), Json::Int(self.img_size as i64)),
            ("patch".into(), Json::Int(self.patch as i64)),
            ("in_ch".into(), Json::Int(self.in_ch as i64)),
            ("d".into(), Json::Int(self.d as i64)),
            ("depth".into(), Json::Int(self.depth as i64)),
            ("heads".into(), Json::Int(self.heads as i64)),
            ("mlp_ratio".into(), Json::Int(self.mlp_ratio as i64)),
            ("n_classes".into(), Json::Int(self.n_classes as i64)),
            ("ffn".into(), Json::Str(self.ffn.clone())),
            ("n_groups".into(), Json::Int(self.n_groups as i64)),
            ("backward".into(), Json::Str(self.backward.clone())),
            ("drop_path".into(), Json::Num(self.drop_path)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            Ok(v.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("missing {k}"))?.to_string())
        };
        let u = |k: &str| -> Result<usize> {
            v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("missing {k}"))
        };
        Ok(Self {
            name: s("name")?,
            img_size: u("img_size")?,
            patch: u("patch")?,
            in_ch: u("in_ch")?,
            d: u("d")?,
            depth: u("depth")?,
            heads: u("heads")?,
            mlp_ratio: u("mlp_ratio")?,
            n_classes: u("n_classes")?,
            ffn: s("ffn")?,
            n_groups: u("n_groups")?,
            backward: s("backward")?,
            drop_path: v.get("drop_path").and_then(Json::as_f64).unwrap_or(0.1),
        })
    }
}

/// Training hyperparameters (paper Table 7 defaults, scaled knobs for the
/// CPU-scale end-to-end runs).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub steps: usize,
    pub batch: usize,
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub weight_decay: f64,
    pub label_smoothing: f64,
    pub mixup_alpha: f64,
    pub cutmix_alpha: f64,
    pub mix_switch_prob: f64,
    pub erase_prob: f64,
    pub ema_decay: f64,
    pub seed: u64,
    /// Evaluate every N steps (0 = only at end).
    pub eval_every: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // Paper Table 7, with steps scaled for CPU runs.
        Self {
            model: "kat-micro".into(),
            steps: 300,
            batch: 32,
            base_lr: 1e-3,
            warmup_steps: 25,
            weight_decay: 0.05,
            label_smoothing: 0.1,
            mixup_alpha: 0.8,
            cutmix_alpha: 1.0,
            mix_switch_prob: 0.5,
            erase_prob: 0.25,
            ema_decay: 0.9999,
            seed: 0,
            eval_every: 0,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("model".into(), Json::Str(self.model.clone())),
            ("steps".into(), Json::Int(self.steps as i64)),
            ("batch".into(), Json::Int(self.batch as i64)),
            ("base_lr".into(), Json::Num(self.base_lr)),
            ("warmup_steps".into(), Json::Int(self.warmup_steps as i64)),
            ("weight_decay".into(), Json::Num(self.weight_decay)),
            ("label_smoothing".into(), Json::Num(self.label_smoothing)),
            ("mixup_alpha".into(), Json::Num(self.mixup_alpha)),
            ("cutmix_alpha".into(), Json::Num(self.cutmix_alpha)),
            ("mix_switch_prob".into(), Json::Num(self.mix_switch_prob)),
            ("erase_prob".into(), Json::Num(self.erase_prob)),
            ("ema_decay".into(), Json::Num(self.ema_decay)),
            ("seed".into(), Json::Int(self.seed as i64)),
            ("eval_every".into(), Json::Int(self.eval_every as i64)),
            ("log_every".into(), Json::Int(self.log_every as i64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let f = |k: &str, dv: f64| v.get(k).and_then(Json::as_f64).unwrap_or(dv);
        let u = |k: &str, dv: usize| v.get(k).and_then(Json::as_usize).unwrap_or(dv);
        Ok(Self {
            model: v
                .get("model")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or(d.model.clone()),
            steps: u("steps", d.steps),
            batch: u("batch", d.batch),
            base_lr: f("base_lr", d.base_lr),
            warmup_steps: u("warmup_steps", d.warmup_steps),
            weight_decay: f("weight_decay", d.weight_decay),
            label_smoothing: f("label_smoothing", d.label_smoothing),
            mixup_alpha: f("mixup_alpha", d.mixup_alpha),
            cutmix_alpha: f("cutmix_alpha", d.cutmix_alpha),
            mix_switch_prob: f("mix_switch_prob", d.mix_switch_prob),
            erase_prob: f("erase_prob", d.erase_prob),
            ema_decay: f("ema_decay", d.ema_decay),
            seed: v.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            eval_every: u("eval_every", d.eval_every),
            log_every: u("log_every", d.log_every),
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("{e}"))?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_param_counts() {
        // Paper Tables 4/6: 5.7M / 22.1M / 86.6M.
        for (name, want_m) in
            [("kat-t", 5.7), ("kat-s", 22.1), ("kat-b", 86.6), ("vit-b", 86.6)]
        {
            let c = ModelConfig::preset(name).unwrap();
            let got = c.param_count() as f64 / 1e6;
            assert!((got - want_m).abs() / want_m < 0.01, "{name}: {got}M");
        }
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(ModelConfig::preset("kat-xxl").is_err());
    }

    #[test]
    fn model_json_roundtrip() {
        let c = ModelConfig::preset("kat-micro").unwrap();
        let back = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn train_json_roundtrip_and_defaults() {
        let c = TrainConfig { steps: 42, ..Default::default() };
        let back = TrainConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        // Missing keys fall back to defaults.
        let sparse = TrainConfig::from_json(
            &Json::parse(r#"{"model":"vit-micro","steps":7}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(sparse.model, "vit-micro");
        assert_eq!(sparse.steps, 7);
        assert_eq!(sparse.batch, TrainConfig::default().batch);
    }

    #[test]
    fn token_geometry() {
        let c = ModelConfig::preset("kat-t").unwrap();
        assert_eq!(c.n_patches(), 196);
        assert_eq!(c.n_tokens(), 197); // the paper's N=197
        let m = ModelConfig::preset("kat-micro").unwrap();
        assert_eq!(m.n_tokens(), 65);
    }
}
