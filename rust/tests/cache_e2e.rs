//! Integration: the content-addressed forward cache end to end — cached
//! responses bit-identical to the unbatched oracle under concurrent
//! duplicate-heavy mixed-model load, the hit/miss/coalesced partition
//! summing to the request totals, singleflight fanning a leader's typed
//! failure to every parked follower, eviction under a tiny byte budget,
//! and the HTTP + flashwire frontends sharing one cache (a row warmed
//! over one transport is a verified hit over the other).

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use flashkat::rational::{forward, Coeffs};
use flashkat::serve::{
    loadgen, BatchPolicy, FlushCause, ModelExecutor, RationalExecutor, Server, SubmitError,
};
use flashkat::util::json::Json;
use flashkat::util::rng::Pcg64;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Concurrent clients over a two-model registry, ~70% of requests drawn
/// from a small shared payload pool (so the hit and coalesced paths see
/// real traffic), every response compared bit-for-bit against the
/// unbatched `rational::forward` oracle — and afterwards the cache's
/// partition invariant: every request was exactly one of hit, miss, or
/// coalesced, and the misses are exactly the requests the executors saw.
#[test]
fn cached_mixed_model_traffic_is_bit_identical_and_counters_partition() {
    let (d_wide, d_narrow) = (96usize, 32usize);
    let mut rng = Pcg64::new(41);
    let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);

    // Shared pool: 5 payloads per model, rows 1-3, oracle precomputed.
    let pool = |d: usize, c: &Coeffs<f32>, salt: u64| -> Vec<(Vec<f32>, usize, Vec<u32>)> {
        (0..5u64)
            .map(|i| {
                let mut rng = Pcg64::with_stream(41, salt + i);
                let rows = 1 + rng.below(3);
                let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                let want = bits(&forward(&x, rows, d, c));
                (x, rows, want)
            })
            .collect()
    };
    let pool_w = pool(d_wide, &cw, 100);
    let pool_n = pool(d_narrow, &cn, 200);

    let server = Server::start_configured(
        vec![
            Box::new(RationalExecutor::new("wide", d_wide, cw.clone()).unwrap()),
            Box::new(RationalExecutor::new("narrow", d_narrow, cn.clone()).unwrap()),
        ],
        BatchPolicy { max_batch: 8, deadline_us: 300, queue_depth: 128, eager: true },
        2,
        None,
        1 << 20,
    )
    .unwrap();

    let clients = 6u64;
    let reqs_each = 30u64;
    std::thread::scope(|s| {
        for client in 0..clients {
            let server = &server;
            let (pool_w, pool_n) = (&pool_w, &pool_n);
            let (cw, cn) = (&cw, &cn);
            s.spawn(move || {
                for i in 0..reqs_each {
                    let mut rng = Pcg64::with_stream(43, client * 1000 + i);
                    let wide = rng.below(2) == 0;
                    let (name, d, c, pool) = if wide {
                        ("wide", d_wide, cw, pool_w)
                    } else {
                        ("narrow", d_narrow, cn, pool_n)
                    };
                    let (x, rows, want) = if rng.below(10) < 7 {
                        let (x, rows, want) = &pool[rng.below(pool.len())];
                        (x.clone(), *rows, want.clone())
                    } else {
                        // Unique payload: always a miss, covers the
                        // insert path interleaved with hits.
                        let rows = 1 + rng.below(3);
                        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                        let want = bits(&forward(&x, rows, d, c));
                        (x, rows, want)
                    };
                    let resp = server.submit(name, x, rows as u32).expect("served");
                    assert_eq!(bits(&resp.y), want, "{name} client {client} req {i}");
                }
            });
        }
    });

    let total_reqs = clients * reqs_each;
    let cs = server.cache_stats().expect("cache attached");
    let stats = server.shutdown().expect("stats");
    assert_eq!(cs.total.requests(), total_reqs, "every request probed the cache exactly once");
    assert_eq!(
        cs.total.hits + cs.total.misses + cs.total.coalesced,
        total_reqs,
        "partition: each probe bumps exactly one counter"
    );
    assert!(cs.total.hits + cs.total.coalesced > 0, "pooled payloads must repeat: {cs:?}");
    assert_eq!(
        cs.total.misses as usize,
        stats.total().requests,
        "misses (leaders + solos) are exactly the executor submissions"
    );
    // The per-model split sums to the global cache totals.
    let sum = |f: &dyn Fn(&flashkat::serve::CacheCounters) -> u64| -> u64 {
        cs.per_model.iter().map(|(_, c)| f(c)).sum()
    };
    assert_eq!(sum(&|c| c.hits), cs.total.hits);
    assert_eq!(sum(&|c| c.misses), cs.total.misses);
    assert_eq!(sum(&|c| c.coalesced), cs.total.coalesced);
    assert_eq!(cs.in_flight, 0, "no flight survives its leader");
}

/// Serial repeat: the second identical request is served off the cache
/// (`FlushCause::Cache`, no batch) with a bit-identical row.
#[test]
fn repeated_request_is_served_from_cache_with_cache_cause() {
    let d = 48;
    let mut rng = Pcg64::new(5);
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let server = Server::start_configured(
        vec![Box::new(RationalExecutor::new("grkan", d, coeffs.clone()).unwrap())],
        BatchPolicy::default(),
        1,
        None,
        1 << 20,
    )
    .unwrap();
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let cold = server.submit("grkan", x.clone(), 1).unwrap();
    assert_ne!(cold.cause, FlushCause::Cache, "first sighting executes");
    let warm = server.submit("grkan", x.clone(), 1).unwrap();
    assert_eq!(warm.cause, FlushCause::Cache);
    assert_eq!(warm.batch_size, 1);
    assert_eq!(bits(&warm.y), bits(&cold.y));
    assert_eq!(bits(&warm.y), bits(&forward(&x, 1, d, &coeffs)));
    let cs = server.cache_stats().unwrap();
    assert_eq!((cs.total.hits, cs.total.misses, cs.total.coalesced), (1, 1, 0));
    let _ = server.shutdown();
}

/// An executor that parks every batch on a gate, then fails it — the
/// leader is provably in flight while followers coalesce, and its typed
/// error must fan out to all of them.
struct GateExecutor {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl ModelExecutor for GateExecutor {
    fn name(&self) -> &str {
        "gate"
    }

    fn d_in(&self) -> usize {
        4
    }

    fn d_out(&self) -> usize {
        4
    }

    fn run(&mut self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) -> anyhow::Result<()> {
        let (lock, cv) = &*self.gate;
        let mut released = lock.lock().unwrap();
        // Bounded wait: a test bug fails loudly instead of wedging CI.
        let deadline = Instant::now() + Duration::from_secs(10);
        while !*released && Instant::now() < deadline {
            let (g, _) = cv.wait_timeout(released, Duration::from_millis(50)).unwrap();
            released = g;
        }
        anyhow::bail!("injected executor failure");
    }
}

/// Leader failure: four identical concurrent requests coalesce onto one
/// executor submission; when that batch fails, all four callers receive
/// the same typed `SubmitError::Failed`, nobody wedges, and the failed
/// flight is closed without inserting anything.
#[test]
fn leader_failure_fans_typed_error_to_all_followers() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let server = Server::start_configured(
        vec![Box::new(GateExecutor { gate: gate.clone() })],
        BatchPolicy { max_batch: 1, deadline_us: 0, queue_depth: 16, eager: true },
        1,
        None,
        1 << 16,
    )
    .unwrap();
    let server = Arc::new(server);

    let threads: Vec<_> = (0..4)
        .map(|_| {
            let server = server.clone();
            std::thread::spawn(move || server.try_submit("gate", vec![1.0; 4], 1))
        })
        .collect();

    // The coalesced counter bumps at lookup time, so it observing 3
    // proves all followers joined the leader's flight *before* the gate
    // releases and the failure propagates.
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.cache_stats().unwrap().total.coalesced < 3 {
        assert!(Instant::now() < deadline, "followers never coalesced");
        std::thread::sleep(Duration::from_millis(2));
    }
    let (lock, cv) = &*gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();

    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    let mut msgs = Vec::new();
    for r in results {
        match r {
            Err(SubmitError::Failed(msg)) => msgs.push(msg),
            other => panic!("expected Failed for every caller, got {other:?}"),
        }
    }
    assert_eq!(msgs.len(), 4);
    assert!(msgs[0].contains("injected executor failure"), "{}", msgs[0]);
    assert!(msgs.iter().all(|m| m == &msgs[0]), "followers receive the leader's exact error");

    let cs = server.cache_stats().unwrap();
    assert_eq!((cs.total.misses, cs.total.coalesced, cs.total.hits), (1, 3, 0));
    assert_eq!(cs.total.inserts, 0, "failures are never cached");
    assert_eq!(cs.in_flight, 0, "the failed flight is closed");
    let _ = server.shutdown();
}

/// A byte budget far smaller than the working set: the cache evicts
/// instead of growing, stays under capacity, and every response — hit,
/// miss after eviction, re-insert — stays bit-identical to the oracle.
#[test]
fn tiny_budget_evicts_and_stays_bit_identical() {
    let d = 32;
    let mut rng = Pcg64::new(9);
    let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
    let server = Server::start_configured(
        vec![Box::new(RationalExecutor::new("grkan", d, coeffs.clone()).unwrap())],
        BatchPolicy::default(),
        1,
        None,
        1024, // ~2-3 single-row entries of width 32
    )
    .unwrap();
    let payloads: Vec<(Vec<f32>, Vec<u32>)> = (0..8)
        .map(|_| {
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want = bits(&forward(&x, 1, d, &coeffs));
            (x, want)
        })
        .collect();
    for pass in 0..3 {
        for (i, (x, want)) in payloads.iter().enumerate() {
            let resp = server.submit("grkan", x.clone(), 1).unwrap();
            assert_eq!(&bits(&resp.y), want, "pass {pass} payload {i}");
        }
    }
    let cs = server.cache_stats().unwrap();
    assert!(cs.total.evictions > 0, "8-entry working set must not fit 1 KiB: {cs:?}");
    assert!(cs.bytes <= cs.capacity_bytes, "{} > {}", cs.bytes, cs.capacity_bytes);
    assert_eq!(cs.total.requests(), 24);
    let _ = server.shutdown();
}

/// Both network frontends over one cached server: a row warmed over
/// HTTP is a verified hit over flashwire (the cache sits below the
/// transports), the HTTP body reports `"cause":"cache"`, the wire
/// response carries `FlushCause::Cache`, and `/metrics` exports the
/// cache counters plus `flashkat_trace_dropped_total`.
#[test]
fn http_and_wire_share_one_cache_and_stay_bit_identical() {
    use flashkat::net::{HttpClient, HttpOptions, HttpServer};
    use flashkat::wire::{WireClient, WireOptions, WireServer};

    let d = 16;
    let mut rng = Pcg64::new(17);
    let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
    let server = Arc::new(
        Server::start_configured(
            vec![Box::new(RationalExecutor::new("grkan", d, coeffs.clone()).unwrap())],
            BatchPolicy::default(),
            1,
            None,
            1 << 20,
        )
        .unwrap(),
    );
    let http_srv = HttpServer::bind("127.0.0.1:0", server.clone(), HttpOptions::default()).unwrap();
    let wire_srv = WireServer::bind("127.0.0.1:0", server.clone(), WireOptions::default()).unwrap();

    let rows = 2usize;
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let want = bits(&forward(&x, rows, d, &coeffs));
    let body = loadgen::infer_body(&x, rows as u32);
    let parse_y = |body: &str| -> (Vec<f32>, String) {
        let j = Json::parse(body).expect("valid json");
        let y: Vec<f32> = j
            .get("y")
            .and_then(Json::as_arr)
            .expect("y array")
            .iter()
            .map(|v| v.as_f64().expect("numeric row") as f32)
            .collect();
        let cause = j.get("cause").and_then(Json::as_str).expect("cause").to_string();
        (y, cause)
    };

    let mut http = HttpClient::connect(http_srv.local_addr()).unwrap();
    let cold = http.post_json("/v1/models/grkan/infer", &body).unwrap();
    assert_eq!(cold.status, 200);
    let (y, cause) = parse_y(&cold.body_str());
    assert_eq!(bits(&y), want, "cold HTTP response matches the oracle through JSON");
    assert_ne!(cause, "cache");
    let warm = http.post_json("/v1/models/grkan/infer", &body).unwrap();
    assert_eq!(warm.status, 200);
    let (y, cause) = parse_y(&warm.body_str());
    assert_eq!(bits(&y), want);
    assert_eq!(cause, "cache", "second identical request is a verified hit");

    // Cross-transport: the wire frontend hits the row HTTP warmed.
    let mut wire = WireClient::connect(wire_srv.local_addr()).unwrap();
    let resp = wire.infer("grkan", &x, rows as u32).unwrap().expect("typed ok");
    assert_eq!(bits(&resp.y), want, "wire replay of the HTTP-warmed row");
    assert_eq!(resp.cause, FlushCause::Cache);

    let metrics = http.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let text = metrics.body_str().to_string();
    assert!(
        text.contains("flashkat_cache_hits_total{model=\"grkan\"} 2"),
        "one HTTP + one wire hit: {text}"
    );
    assert!(text.contains("flashkat_cache_misses_total{model=\"grkan\"} 1"), "{text}");
    assert!(text.contains("flashkat_trace_dropped_total 0"), "{text}");

    let cs = server.cache_stats().unwrap();
    assert_eq!((cs.total.hits, cs.total.misses), (2, 1));
    let _ = wire_srv.shutdown();
    let _ = http_srv.shutdown();
}
