//! End-to-end: the HTTP/JSON frontend over a **sharded** serve engine.
//!
//! Acceptance properties (ISSUE 4):
//! - responses over loopback HTTP are **bit-identical** to in-process
//!   `Server::submit` for the same requests, across a mixed multi-model
//!   registry on ≥2 shards, under concurrent load;
//! - per-model stats sum exactly to the server totals;
//! - a saturated admission queue surfaces as `429` with a `Retry-After`
//!   header — never a hang, never a dropped response;
//! - malformed traffic maps to 4xx statuses and the server keeps serving.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;
use flashkat::net::{HttpClient, HttpOptions, HttpServer, Limits};
use flashkat::rational::Coeffs;
// The canonical wire encoding — shared with the bench client so the
// test exercises the real format, not a private copy of it.
use flashkat::serve::loadgen::infer_body;
use flashkat::serve::{BatchPolicy, ModelExecutor, RationalExecutor, Server};
use flashkat::util::json::Json;
use flashkat::util::rng::Pcg64;

const D_WIDE: usize = 96;
const D_NARROW: usize = 32;

fn registry(seed: u64) -> Vec<Box<dyn ModelExecutor>> {
    let mut rng = Pcg64::new(seed);
    let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
    vec![
        Box::new(RationalExecutor::new("wide", D_WIDE, cw).unwrap()),
        Box::new(RationalExecutor::new("narrow", D_NARROW, cn).unwrap()),
    ]
}

fn parse_y(body: &str) -> Vec<f32> {
    Json::parse(body)
        .unwrap()
        .get("y")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

/// The headline acceptance test: concurrent mixed-model traffic over a
/// 2-shard HTTP server, every response compared bitwise against an
/// identically-seeded in-process server answering the same requests.
#[test]
fn http_responses_bit_identical_to_in_process_submit() {
    let seed = 1234;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let served = Server::start_sharded(
        registry(seed),
        BatchPolicy { max_batch: 8, deadline_us: 400, queue_depth: 128, eager: true },
        2,
    )
    .unwrap();
    assert_eq!(served.shards(), 2);
    let http =
        HttpServer::bind("127.0.0.1:0", Arc::new(served), HttpOptions::default()).unwrap();
    let addr = http.local_addr();

    let clients = 6u64;
    let reqs_each = 12u64;
    std::thread::scope(|s| {
        for client in 0..clients {
            let oracle = &oracle;
            s.spawn(move || {
                let mut conn = HttpClient::connect(addr).expect("connect");
                for i in 0..reqs_each {
                    let mut rng = Pcg64::with_stream(seed, client * 1000 + i);
                    let (name, idx, d) = if (client + i) % 2 == 0 {
                        ("wide", 0u32, D_WIDE)
                    } else {
                        ("narrow", 1u32, D_NARROW)
                    };
                    let rows = 1 + rng.below(3) as u32;
                    let x: Vec<f32> =
                        (0..rows as usize * d).map(|_| rng.normal_f32()).collect();
                    let want =
                        oracle.submit_at(idx, x.clone(), rows).expect("oracle submit").y;
                    let resp = conn
                        .post_json(&format!("/v1/models/{name}/infer"), &infer_body(&x, rows))
                        .expect("http request");
                    assert_eq!(resp.status, 200, "{}", resp.body_str());
                    let y = parse_y(&resp.body_str());
                    assert_eq!(y, want, "client {client} req {i} ({name}): HTTP != in-process");
                }
            });
        }
    });

    let stats = http.shutdown().expect("stats");
    let total = stats.total();
    let n = (clients * reqs_each) as usize;
    assert_eq!(total.requests, n);
    assert_eq!(total.failed, 0);
    // Per-model split sums exactly to the totals, counter by counter.
    assert_eq!(stats.per_model.len(), 2);
    let req_sum: usize = stats.per_model.iter().map(|m| m.stats.requests).sum();
    let row_sum: usize = stats.per_model.iter().map(|m| m.stats.rows).sum();
    let batch_sum: usize = stats.per_model.iter().map(|m| m.stats.batches).sum();
    assert_eq!(req_sum, total.requests);
    assert_eq!(row_sum, total.rows);
    assert_eq!(batch_sum, total.batches);
    assert_eq!(stats.model("wide").unwrap().stats.requests, n / 2);
    assert_eq!(stats.model("narrow").unwrap().stats.requests, n / 2);
    assert_eq!(stats.shard_peaks.len(), 2);
    oracle.shutdown();
}

/// An executor that blocks until released (counts entries so the test
/// can wedge the queue deterministically).
struct Gate {
    entered: Arc<AtomicUsize>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl ModelExecutor for Gate {
    fn name(&self) -> &str {
        "gated"
    }
    fn d_in(&self) -> usize {
        4
    }
    fn d_out(&self) -> usize {
        4
    }
    fn run(&mut self, x: &[f32], _rows: usize, out: &mut Vec<f32>) -> Result<()> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let (lock, cv) = &*self.release;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        out.clear();
        out.extend_from_slice(x);
        Ok(())
    }
}

/// Saturate the admission queue behind a wedged executor: concurrent
/// HTTP requests must split into served-later (200 after release) and
/// shed (429 + Retry-After) — with **every** request answered.
#[test]
fn saturated_queue_returns_429_with_retry_after_never_hangs() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let gate = Gate { entered: entered.clone(), release: release.clone() };
    let depth = 2;
    let server = Server::start(
        vec![Box::new(gate)],
        BatchPolicy { max_batch: 1, deadline_us: 100, queue_depth: depth, eager: true },
    )
    .unwrap();
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Arc::new(server),
        HttpOptions { conn_threads: 12, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr();

    // 1 wedged in the executor + `depth` queued; everything beyond that
    // must be shed as 429.
    let fired = 9usize;
    let outcomes: Vec<(u16, Option<String>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..fired {
            let release = release.clone();
            let entered = entered.clone();
            handles.push(s.spawn(move || {
                // Thread 0 wedges the executor first; the rest pile on
                // once it is provably inside `run`.
                if i > 0 {
                    while entered.load(Ordering::SeqCst) == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
                if i == fired - 1 {
                    // Last thread opens the gate after everyone else has
                    // had time to be admitted or shed.
                    std::thread::sleep(std::time::Duration::from_millis(150));
                    let (lock, cv) = &*release;
                    *lock.lock().unwrap() = true;
                    cv.notify_all();
                }
                let mut conn = HttpClient::connect(addr).expect("connect");
                let resp = conn
                    .post_json("/v1/models/gated/infer", &infer_body(&[0.5; 4], 1))
                    .expect("every request gets an answer");
                (resp.status, resp.header("retry-after").map(str::to_string))
            }));
        }
        handles.into_iter().map(|h| h.join().expect("no hung client")).collect()
    });

    let ok = outcomes.iter().filter(|(s, _)| *s == 200).count();
    let shed: Vec<_> = outcomes.iter().filter(|(s, _)| *s == 429).collect();
    assert_eq!(ok + shed.len(), fired, "only 200s and 429s: {outcomes:?}");
    assert!(ok >= 1, "the wedged request itself completes after release");
    assert!(!shed.is_empty(), "a {depth}-deep queue under {fired} concurrent requests must shed");
    for (_, retry) in &shed {
        assert_eq!(retry.as_deref(), Some("1"), "429 carries Retry-After");
    }
    let stats = http.shutdown().expect("stats");
    assert_eq!(stats.total().requests, ok, "every 200 is a served request");
    assert!(stats.peak_queued <= depth);
}

/// `/metrics` exports the per-ticket latency `LogHist`s as real
/// Prometheus histograms and the executor payload traffic as labeled
/// counters.  The scrape is *parsed*, not just substring-matched: bucket
/// `le` bounds must ascend, cumulative counts must be monotone and end
/// at `_count`, and the `+Inf` bucket must equal `_count` exactly.
#[test]
fn metrics_scrape_parses_as_prometheus_histograms() {
    let server = Server::start(registry(42), BatchPolicy::default()).unwrap();
    let http =
        HttpServer::bind("127.0.0.1:0", Arc::new(server), HttpOptions::default()).unwrap();
    let addr = http.local_addr();
    let mut conn = HttpClient::connect(addr).unwrap();

    let served = 5usize;
    let rows_per_req = 2u32;
    let mut rng = Pcg64::new(43);
    for i in 0..served {
        let x: Vec<f32> =
            (0..rows_per_req as usize * D_WIDE).map(|_| rng.normal_f32()).collect();
        let r = conn
            .post_json("/v1/models/wide/infer", &infer_body(&x, rows_per_req))
            .unwrap();
        assert_eq!(r.status, 200, "req {i}: {}", r.body_str());
    }

    let scrape = conn.get("/metrics").unwrap().body_str().into_owned();

    // Traffic counters: rows * d * 4 bytes per direction, exactly.
    let total_rows = served as u64 * rows_per_req as u64;
    for stream in ["in", "out"] {
        let line = format!(
            "flashkat_traffic_bytes_total{{model=\"wide\",stream=\"{stream}\"}} {}",
            total_rows * D_WIDE as u64 * 4
        );
        assert!(scrape.contains(&line), "missing {line:?} in\n{scrape}");
    }

    for metric in ["flashkat_queue_wait_us", "flashkat_exec_us"] {
        assert!(
            scrape.contains(&format!("# TYPE {metric} histogram")),
            "{metric} lacks a TYPE line:\n{scrape}"
        );
        // Parse every wide-model bucket line into (le, cumulative).
        let prefix = format!("{metric}_bucket{{model=\"wide\",le=\"");
        let mut buckets: Vec<(f64, u64)> = Vec::new();
        for line in scrape.lines() {
            let Some(rest) = line.strip_prefix(&prefix) else { continue };
            let (le_str, count_str) = rest.split_once("\"} ").expect("bucket line shape");
            let le =
                if le_str == "+Inf" { f64::INFINITY } else { le_str.parse::<f64>().unwrap() };
            buckets.push((le, count_str.parse::<u64>().unwrap()));
        }
        assert!(buckets.len() >= 2, "{metric}: at least one finite bucket plus +Inf");
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0, "{metric}: le bounds not ascending: {buckets:?}");
            assert!(w[1].1 >= w[0].1, "{metric}: cumulative counts decreased: {buckets:?}");
        }
        let (last_le, last_cum) = *buckets.last().unwrap();
        assert_eq!(last_le, f64::INFINITY, "{metric}: final bucket must be +Inf");
        assert_eq!(last_cum, served as u64, "{metric}: +Inf bucket counts every ticket");
        assert!(
            scrape.contains(&format!("{metric}_count{{model=\"wide\"}} {served}")),
            "{metric}_count:\n{scrape}"
        );
        assert!(
            scrape.contains(&format!("{metric}_sum{{model=\"wide\"}}")),
            "{metric}_sum:\n{scrape}"
        );
    }
    // The untouched model exports empty histograms (count 0), not nothing
    // — scrapers want stable series.
    assert!(
        scrape.contains("flashkat_exec_us_count{model=\"narrow\"} 0"),
        "idle model still exports:\n{scrape}"
    );

    let stats = http.shutdown().expect("stats");
    assert_eq!(stats.total().requests, served);
}

/// Protocol-level rejects: malformed bodies, unknown models, bad
/// routes/methods, oversized payloads — each the right status, and the
/// server keeps serving afterwards.
#[test]
fn malformed_traffic_gets_4xx_and_service_survives() {
    let server = Server::start_sharded(registry(9), BatchPolicy::default(), 2).unwrap();
    let http = HttpServer::bind(
        "127.0.0.1:0",
        Arc::new(server),
        HttpOptions { limits: Limits { max_body_bytes: 4096, ..Default::default() }, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr();
    let mut conn = HttpClient::connect(addr).unwrap();

    // Malformed JSON → 400 (the CI curl smoke's exact case).
    let r = conn.post_json("/v1/models/wide/infer", "{\"x\":").unwrap();
    assert_eq!(r.status, 400);
    // Wrong shape → 400.
    let r = conn.post_json("/v1/models/wide/infer", &infer_body(&[1.0; 3], 1)).unwrap();
    assert_eq!(r.status, 400);
    // Raw control byte inside a JSON string → 400 (json hardening).
    let r = conn.post_json("/v1/models/wide/infer", "{\"x\":[1],\"note\":\"a\u{1}b\"}").unwrap();
    assert_eq!(r.status, 400);
    // Unknown model → 404; unknown route → 404; wrong method → 405.
    let r = conn.post_json("/v1/models/nope/infer", &infer_body(&[0.0; 4], 1)).unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(conn.get("/v1/nope").unwrap().status, 404);
    assert_eq!(conn.get("/v1/models/wide/infer").unwrap().status, 405);
    // Oversized body → 413.  Declared length is enough — the server
    // rejects before reading the body (so a client can't be forced to
    // upload megabytes just to be refused).  Raw socket: the response
    // arrives while the body was never sent.
    {
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.write_all(
            b"POST /v1/models/wide/infer HTTP/1.1\r\ncontent-length: 999999\r\n\r\n",
        )
        .unwrap();
        let mut buf = [0u8; 64];
        let n = raw.read(&mut buf).unwrap();
        let head = String::from_utf8_lossy(&buf[..n]).into_owned();
        assert!(head.starts_with("HTTP/1.1 413 "), "{head}");
    }

    // The server still serves good traffic afterwards.
    let mut conn = HttpClient::connect(addr).unwrap();
    let mut rng = Pcg64::new(10);
    let x: Vec<f32> = (0..D_WIDE).map(|_| rng.normal_f32()).collect();
    let r = conn.post_json("/v1/models/wide/infer", &infer_body(&x, 1)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());

    // Observability endpoints agree with what just happened.
    assert_eq!(conn.get("/healthz").unwrap().status, 200);
    let models = conn.get("/v1/models").unwrap();
    assert_eq!(models.status, 200);
    let listed = Json::parse(&models.body_str()).unwrap();
    assert_eq!(listed.get("models").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(listed.get("shards").unwrap().as_usize(), Some(2));
    let scrape = conn.get("/metrics").unwrap().body_str().into_owned();
    assert!(scrape.contains("flashkat_serve_requests_total{model=\"wide\"} 1"), "{scrape}");
    assert!(scrape.contains("flashkat_http_requests_total{code=\"200\"}"), "{scrape}");
    assert!(scrape.contains("flashkat_http_requests_total{code=\"400\"}"), "{scrape}");

    let stats = http.shutdown().expect("stats");
    assert_eq!(stats.total().requests, 1, "only the good request reached an executor");
    assert_eq!(stats.total().failed, 0);
}
