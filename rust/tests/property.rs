//! Property-based tests over coordinator invariants.
//!
//! The offline registry has no proptest, so this file uses a seeded
//! random-case runner (`cases`) with shrink-free minimal reporting — each
//! property is exercised over many generated configurations.

use flashkat::coordinator::augment::{self, AugmentConfig};
use flashkat::coordinator::schedule::CosineSchedule;
use flashkat::rational::accumulate::{backward, Strategy};
use flashkat::rational::Coeffs;
use flashkat::util::json::Json;
use flashkat::util::rng::Pcg64;

fn cases(n: usize, mut f: impl FnMut(u64, &mut Pcg64)) {
    for seed in 0..n as u64 {
        let mut rng = Pcg64::new(seed * 7919 + 13);
        f(seed, &mut rng);
    }
}

#[test]
fn prop_augment_preserves_label_mass() {
    // For ANY augmentation config and batch, soft labels remain valid
    // probability distributions.
    cases(40, |seed, rng| {
        let n_classes = 2 + rng.below(20);
        let img_size = 4 + 2 * rng.below(7);
        let batch = 1 + rng.below(9);
        let cfg = AugmentConfig {
            n_classes,
            img_size,
            channels: 3,
            label_smoothing: rng.uniform_range(0.0, 0.3),
            mixup_alpha: rng.uniform_range(0.1, 2.0),
            cutmix_alpha: rng.uniform_range(0.1, 2.0),
            switch_prob: rng.uniform(),
            mix_prob: rng.uniform(),
            erase_prob: rng.uniform(),
        };
        let mut images = vec![0.3f32; batch * img_size * img_size * 3];
        let labels: Vec<usize> = (0..batch).map(|_| rng.below(n_classes)).collect();
        let soft = augment::apply(&cfg, &mut images, &labels, rng);
        for b in 0..batch {
            let row = &soft[b * n_classes..(b + 1) * n_classes];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "seed {seed}: mass {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0001).contains(&p)), "seed {seed}");
        }
        assert!(images.iter().all(|v| v.is_finite()), "seed {seed}");
    });
}

#[test]
fn prop_schedule_bounded_and_warmup_monotone() {
    cases(60, |seed, rng| {
        let base = rng.uniform_range(1e-5, 1e-1);
        let warmup = rng.below(50);
        let total = warmup + 1 + rng.below(500);
        let s = CosineSchedule::new(base, warmup, total);
        let mut prev = 0.0;
        for step in 1..=total {
            let lr = s.lr(step);
            assert!(lr.is_finite() && lr > 0.0, "seed {seed} step {step}");
            assert!(lr <= base * 1.0001, "seed {seed}: lr {lr} > base {base}");
            if step <= warmup {
                assert!(lr >= prev, "seed {seed}: warmup not monotone");
            }
            prev = lr;
        }
    });
}

#[test]
fn prop_accumulation_strategies_agree_in_f64() {
    // In f64 every accumulation order gives (numerically) the same result
    // — the strategies differ ONLY in rounding behaviour.
    cases(15, |seed, rng| {
        let n_g = 1 << rng.below(3);
        let d_g = 1 + rng.below(12);
        let d = n_g * d_g;
        let rows = 1 + rng.below(40);
        let x: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let dout: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let c = Coeffs::<f64>::randn(n_g, 2 + rng.below(5), 1 + rng.below(4), rng);
        let (_, da0, db0) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);
        let s_block = 1 + rng.below(rows + 4);
        for strat in
            [Strategy::BlockTree { s_block }, Strategy::PairwiseFull, Strategy::BlockSequential { s_block }]
        {
            let (_, da, db) = backward(&x, &dout, rows, d, &c, strat);
            let scale = da0.iter().map(|v| v.abs()).fold(1.0, f64::max);
            for (u, v) in da.iter().zip(&da0) {
                assert!((u - v).abs() / scale < 1e-9, "seed {seed} {strat:?}");
            }
            let scale = db0.iter().map(|v| v.abs()).fold(1.0, f64::max);
            for (u, v) in db.iter().zip(&db0) {
                assert!((u - v).abs() / scale < 1e-9, "seed {seed} {strat:?}");
            }
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Int(rng.next_u64() as i64 >> rng.below(40)),
            3 => {
                let s: String = (0..rng.below(12))
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5)).map(|i| (format!("k{i}"), gen(rng, depth - 1))).collect(),
            ),
        }
    }
    cases(200, |seed, rng| {
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(v, back, "seed {seed}");
    });
}

#[test]
fn prop_gpusim_work_monotone_in_blocks() {
    // More blocks of identical work never finish earlier.
    use flashkat::gpusim::engine::{Instr, Kernel, MemLevel};
    use flashkat::gpusim::{simulate, GpuConfig};
    struct K(u64);
    impl Kernel for K {
        fn name(&self) -> String {
            "prop".into()
        }
        fn num_blocks(&self) -> u64 {
            self.0
        }
        fn warps_per_block(&self) -> u32 {
            2
        }
        fn warp_program(&self, _b: u64, _w: u32, out: &mut Vec<Instr>) {
            out.push(Instr::Load { level: MemLevel::Hbm, bytes: 128 });
            out.push(Instr::Compute { n: 4, flops: 128 });
            out.push(Instr::Store { level: MemLevel::Hbm, bytes: 128 });
        }
    }
    let cfg = GpuConfig::rtx4060ti();
    let mut prev = 0;
    for blocks in [10u64, 100, 1000, 5000, 20000] {
        let r = simulate(&cfg, &K(blocks));
        assert!(r.elapsed_cycles >= prev, "blocks {blocks}");
        prev = r.elapsed_cycles;
    }
}

#[test]
fn prop_batcher_interleaved_multikey_invariants() {
    // Virtual-clock property: under ANY interleaving of admissions to a
    // hot key and several cold keys, with the executor polling every
    // step, (a) each bucket releases in FIFO order, (b) no request —
    // cold keys included — waits more than deadline + one poll interval,
    // and (c) backpressure counts requests across ALL buckets.
    use flashkat::serve::{BatchPolicy, Batcher, ShapeKey};
    use std::collections::BTreeMap;

    cases(30, |seed, rng| {
        let n_keys = 2 + rng.below(3); // key 0 is hot, the rest cold
        let max_step = 1 + rng.below(30) as u64;
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(6),
            deadline_us: 20 + rng.below(300) as u64,
            queue_depth: 4 + rng.below(24),
            eager: false,
        };
        let mut b = Batcher::new(policy);
        let key = |k: usize| ShapeKey { model: k as u32, d: 8 * (k as u32 + 1) };
        let mut now = 0u64;
        let mut outstanding = 0usize;
        let mut enq: BTreeMap<u64, u64> = BTreeMap::new(); // id -> enq time
        let mut last_id: Vec<Option<u64>> = vec![None; n_keys];

        let check_release = |batch: &flashkat::serve::Batch,
                             now: u64,
                             enq: &mut BTreeMap<u64, u64>,
                             last_id: &mut Vec<Option<u64>>,
                             outstanding: &mut usize| {
            let k = batch.key.model as usize;
            for t in &batch.tickets {
                // (a) per-bucket FIFO: ids strictly increase per key.
                if let Some(prev) = last_id[k] {
                    assert!(t.id > prev, "seed {seed}: key {k} out of order");
                }
                last_id[k] = Some(t.id);
                // (b) bounded wait: released no later than one poll
                // interval past the deadline.
                let waited = now - enq.remove(&t.id).expect("admitted ticket");
                assert!(
                    waited <= policy.deadline_us + max_step,
                    "seed {seed}: key {k} waited {waited}us (deadline {}, step {max_step})",
                    policy.deadline_us
                );
                *outstanding -= 1;
            }
        };

        for step in 0..400usize {
            now += 1 + rng.below(max_step as usize) as u64;
            // Hot key admits most steps; cold keys occasionally.
            let k = if rng.below(4) < 3 { 0 } else { 1 + rng.below(n_keys - 1) };
            match b.admit(key(k), now) {
                Some(t) => {
                    enq.insert(t.id, now);
                    outstanding += 1;
                }
                None => {
                    // (c) refusal happens exactly at the cross-bucket cap.
                    assert_eq!(
                        outstanding, policy.queue_depth,
                        "seed {seed} step {step}: refused below depth"
                    );
                }
            }
            assert_eq!(b.queued(), outstanding, "seed {seed}: queued() counts all buckets");
            // Busy executor polls every step (idle=false): Full and
            // Deadline releases only.
            while let Some(batch) = b.pop(now, false) {
                check_release(&batch, now, &mut enq, &mut last_id, &mut outstanding);
            }
        }
        // Terminal drain returns every remaining ticket exactly once, in
        // per-bucket FIFO order (the wait bound no longer applies).
        for batch in b.drain() {
            let k = batch.key.model as usize;
            for t in &batch.tickets {
                if let Some(prev) = last_id[k] {
                    assert!(t.id > prev, "seed {seed}: drain out of order on key {k}");
                }
                last_id[k] = Some(t.id);
                assert!(enq.remove(&t.id).is_some(), "seed {seed}: drained unknown ticket");
                outstanding -= 1;
            }
        }
        assert_eq!(outstanding, 0, "seed {seed}: every admitted ticket was released");
        assert!(enq.is_empty());
        assert_eq!(b.queued(), 0);
    });
}

#[test]
fn prop_rational_forward_finite_for_wild_inputs() {
    // Safe-PAU property: Q >= 1 means no poles for ANY coefficients/x.
    cases(30, |seed, rng| {
        let c = Coeffs::<f32>::randn(4, 6, 4, rng);
        let rows = 3;
        let d = 16;
        let x: Vec<f32> = (0..rows * d)
            .map(|_| (rng.normal() * 10f64.powi(rng.below(6) as i32 - 3)) as f32)
            .collect();
        let y = flashkat::rational::forward(&x, rows, d, &c);
        assert!(y.iter().all(|v| v.is_finite()), "seed {seed}");
    });
}

#[test]
fn prop_simd_dispatch_bitwise_matches_scalar_oracle_for_random_bit_patterns() {
    // The DESIGN.md §14 contract under adversarial inputs: push raw
    // random bit patterns — with NaN / ±0 / subnormal / ±Inf lanes forced
    // at fixed strides — and random non-lane-multiple widths through the
    // dispatched forward/backward (SIMD under `--features simd`, the same
    // scalar code otherwise) and the scalar oracle.  Everything must
    // agree bit for bit; NaNs compare as a class (payloads are not
    // pinned by IEEE-754 across scalar/vector instruction forms).
    use flashkat::rational::kernel::{backward_row_seg, SegAccum, TileAcc};
    use flashkat::rational::{forward_elem, Float};

    fn specials32(i: usize) -> f32 {
        [f32::NAN, 0.0, -0.0, f32::MIN_POSITIVE / 64.0, -f32::MIN_POSITIVE / 8.0, f32::INFINITY, f32::NEG_INFINITY][i % 7]
    }
    fn specials64(i: usize) -> f64 {
        [f64::NAN, 0.0, -0.0, f64::MIN_POSITIVE / 64.0, -f64::MIN_POSITIVE / 8.0, f64::INFINITY, f64::NEG_INFINITY][i % 7]
    }

    cases(40, |seed, rng| {
        let (m1, n) = (1 + rng.below(6), 1 + rng.below(4));
        // Widths biased away from lane multiples: 8k+r covers every tail
        // remainder for both lane counts (8 and 4) over the seeds.
        let w = 1 + rng.below(40);
        let a32: Vec<f32> = (0..m1).map(|_| rng.normal_f32()).collect();
        let b32: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let x32: Vec<f32> = (0..w)
            .map(|i| {
                if i % 5 == 3 {
                    specials32(i / 5 + seed as usize)
                } else {
                    f32::from_bits(rng.next_u64() as u32)
                }
            })
            .collect();
        let dout32: Vec<f32> = (0..w).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();

        let bits32 = |u: f32, v: f32| u.to_bits() == v.to_bits() || (u.is_nan() && v.is_nan());

        // f32 forward.
        let mut out = vec![0f32; w];
        <f32 as Float>::forward_seg_fast(&x32, &mut out, &a32, &b32);
        for (k, &x) in x32.iter().enumerate() {
            assert!(bits32(out[k], forward_elem(x, &a32, &b32)), "seed {seed} fwd32 k={k}");
        }
        // f32 backward (tree and sequential tile variants).
        for tree in [true, false] {
            let mut dx_o = vec![0f32; w];
            let mut oracle = TileAcc::<f32>::new(m1, n, tree);
            backward_row_seg(&x32, &dout32, &mut dx_o, &a32, &b32, &mut oracle);
            let mut dx_d = vec![0f32; w];
            let mut disp = <<f32 as Float>::Acc as SegAccum<f32>>::new(m1, n, tree);
            disp.row_seg(&x32, &dout32, &mut dx_d, &a32, &b32);
            for k in 0..w {
                assert!(bits32(dx_d[k], dx_o[k]), "seed {seed} dx32 k={k} tree={tree}");
            }
            let (da_o, db_o) = oracle.finish();
            let (da_d, db_d) = disp.finish();
            for i in 0..m1 {
                assert!(bits32(da_d[i], da_o[i]), "seed {seed} da32[{i}] tree={tree}");
            }
            for j in 0..n {
                assert!(bits32(db_d[j], db_o[j]), "seed {seed} db32[{j}] tree={tree}");
            }
        }

        // f64: same drill from raw u64 bit patterns.
        let bits64 = |u: f64, v: f64| u.to_bits() == v.to_bits() || (u.is_nan() && v.is_nan());
        let a64: Vec<f64> = (0..m1).map(|_| rng.normal()).collect();
        let b64: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x64: Vec<f64> = (0..w)
            .map(|i| {
                if i % 5 == 3 {
                    specials64(i / 5 + seed as usize)
                } else {
                    f64::from_bits(rng.next_u64())
                }
            })
            .collect();
        let dout64: Vec<f64> = (0..w).map(|_| f64::from_bits(rng.next_u64())).collect();
        let mut out = vec![0f64; w];
        <f64 as Float>::forward_seg_fast(&x64, &mut out, &a64, &b64);
        for (k, &x) in x64.iter().enumerate() {
            assert!(bits64(out[k], forward_elem(x, &a64, &b64)), "seed {seed} fwd64 k={k}");
        }
        let mut dx_o = vec![0f64; w];
        let mut oracle = TileAcc::<f64>::new(m1, n, true);
        backward_row_seg(&x64, &dout64, &mut dx_o, &a64, &b64, &mut oracle);
        let mut dx_d = vec![0f64; w];
        let mut disp = <<f64 as Float>::Acc as SegAccum<f64>>::new(m1, n, true);
        disp.row_seg(&x64, &dout64, &mut dx_d, &a64, &b64);
        for k in 0..w {
            assert!(bits64(dx_d[k], dx_o[k]), "seed {seed} dx64 k={k}");
        }
        let (da_o, db_o) = oracle.finish();
        let (da_d, db_d) = disp.finish();
        for i in 0..m1 {
            assert!(bits64(da_d[i], da_o[i]), "seed {seed} da64[{i}]");
        }
        for j in 0..n {
            assert!(bits64(db_d[j], db_o[j]), "seed {seed} db64[{j}]");
        }
    });
}

#[test]
fn prop_wire_frames_round_trip_any_payload() {
    // ANY msg-type with ANY payload (arbitrary bytes, up to the cap)
    // survives write → read bit-exactly, including pipelined sequences
    // on one stream.
    use flashkat::wire::frame::{read_frame, write_frame, FrameOutcome, MsgType, WireLimits};
    use std::io::Cursor;
    use std::sync::atomic::AtomicBool;

    cases(60, |seed, rng| {
        let limits = WireLimits::default();
        let stop = AtomicBool::new(false);
        let n_frames = 1 + rng.below(4);
        let mut raw = Vec::new();
        let mut sent = Vec::new();
        for _ in 0..n_frames {
            let msg_type = MsgType::ALL[rng.below(MsgType::ALL.len())];
            let payload: Vec<u8> =
                (0..rng.below(2048)).map(|_| rng.next_u64() as u8).collect();
            write_frame(&mut raw, msg_type, &payload).unwrap();
            sent.push((msg_type, payload));
        }
        let mut cur = Cursor::new(raw);
        for (i, (msg_type, payload)) in sent.iter().enumerate() {
            match read_frame(&mut cur, &limits, &stop).unwrap() {
                FrameOutcome::Ok(f) => {
                    assert_eq!(f.msg_type, *msg_type, "seed {seed} frame {i}");
                    assert_eq!(&f.payload, payload, "seed {seed} frame {i}");
                }
                other => panic!("seed {seed} frame {i}: {other:?}"),
            }
        }
        assert!(
            matches!(read_frame(&mut cur, &limits, &stop).unwrap(), FrameOutcome::Closed),
            "seed {seed}: clean EOF after the last frame"
        );
    });
}

#[test]
fn prop_wire_codec_rejects_abuse_without_panicking_or_over_reading() {
    // The frame codec's hard contract: 1-byte truncations anywhere, a
    // length field past the cap, unknown msg-types, and random garbage
    // must all error (never panic, never hang) — and a reject decided
    // at the header must not have consumed a single payload byte.
    use flashkat::wire::frame::{
        read_frame, write_frame, FrameOutcome, MsgType, WireLimits, HEADER_LEN,
    };
    use std::io::Cursor;
    use std::sync::atomic::AtomicBool;

    cases(80, |seed, rng| {
        let limits = WireLimits { max_payload_bytes: 4096, ..Default::default() };
        let stop = AtomicBool::new(false);
        let msg_type = MsgType::ALL[rng.below(MsgType::ALL.len())];
        let payload: Vec<u8> = (0..1 + rng.below(256)).map(|_| rng.next_u64() as u8).collect();
        let mut good = Vec::new();
        write_frame(&mut good, msg_type, &payload).unwrap();

        // (1) Truncate at a random cut: Bad (mid-frame) — never Ok.
        let cut = 1 + rng.below(good.len() - 1);
        match read_frame(&mut Cursor::new(good[..cut].to_vec()), &limits, &stop).unwrap() {
            FrameOutcome::Bad { .. } => {}
            other => panic!("seed {seed}: cut {cut} gave {other:?}"),
        }

        // (2) Length over the cap: rejected at the header, zero payload
        // bytes consumed.
        let mut oversized = good.clone();
        let lie = limits.max_payload_bytes as u32 + 1 + rng.below(1 << 20) as u32;
        oversized[4..8].copy_from_slice(&lie.to_le_bytes());
        let mut cur = Cursor::new(oversized);
        match read_frame(&mut cur, &limits, &stop).unwrap() {
            FrameOutcome::Bad { msg, .. } => assert!(msg.contains("cap"), "seed {seed}: {msg}"),
            other => panic!("seed {seed}: oversized gave {other:?}"),
        }
        assert_eq!(cur.position(), HEADER_LEN as u64, "seed {seed}: over-read past header");

        // (3) Unknown msg-type: same no-over-read guarantee.
        let mut unknown = good.clone();
        unknown[3] = 8 + rng.below(247) as u8; // anything past MsgType::ALL
        let mut cur = Cursor::new(unknown);
        match read_frame(&mut cur, &limits, &stop).unwrap() {
            FrameOutcome::Bad { msg, .. } => {
                assert!(msg.contains("unknown msg-type"), "seed {seed}: {msg}")
            }
            other => panic!("seed {seed}: unknown type gave {other:?}"),
        }
        assert_eq!(cur.position(), HEADER_LEN as u64, "seed {seed}: over-read past header");

        // (4) Random garbage never panics and never yields Ok unless it
        // happens to start with a valid header (vanishingly unlikely:
        // the magic would have to be literal "FW").
        let garbage: Vec<u8> =
            (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        let outcome = read_frame(&mut Cursor::new(garbage.clone()), &limits, &stop).unwrap();
        if garbage.first() != Some(&b'F') {
            assert!(
                !matches!(outcome, FrameOutcome::Ok(_)),
                "seed {seed}: garbage decoded as a frame"
            );
        }
    });
}

#[test]
fn prop_wire_infer_messages_round_trip_random_floats_bit_exactly() {
    // Every f32 bit pattern the generator produces — including
    // subnormals and negative zero — survives the typed message codecs
    // unchanged; mutated payloads never panic the decoder.
    use flashkat::wire::{InferRequest, InferResponse};

    cases(60, |seed, rng| {
        let rows = 1 + rng.below(4) as u32;
        let dim = 1 + rng.below(64) as u32;
        let x: Vec<f32> = (0..(rows * dim) as usize)
            .map(|_| {
                // Mix plain normals with raw bit patterns (any u32 is a
                // valid f32 bit pattern), filtered to finite for the
                // request path, which rejects non-finite by contract.
                if rng.bernoulli(0.5) {
                    rng.normal_f32()
                } else {
                    let v = f32::from_bits(rng.next_u64() as u32);
                    if v.is_finite() { v } else { -0.0 }
                }
            })
            .collect();
        let req = InferRequest { model: format!("m{seed}"), rows, dim, x: x.clone() };
        let back = InferRequest::decode(&req.encode()).unwrap();
        let bits: Vec<u32> = back.x.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "seed {seed}: request floats changed bits");

        // Responses may carry any bit pattern, finite or not.
        let y: Vec<f32> = (0..(rows * dim) as usize)
            .map(|_| f32::from_bits(rng.next_u64() as u32))
            .collect();
        let resp = InferResponse {
            y: y.clone(),
            batch_size: 1 + rng.below(64) as u32,
            cause: flashkat::serve::FlushCause::ALL[rng.below(4)],
        };
        let back = InferResponse::decode(&resp.encode()).unwrap();
        let bits: Vec<u32> = back.y.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "seed {seed}: response floats changed bits");
        assert_eq!(back.batch_size, resp.batch_size);

        // A single flipped/truncated byte must error or decode — never
        // panic, never over-read.
        let mut mutated = req.encode();
        if !mutated.is_empty() {
            let at = rng.below(mutated.len());
            if rng.bernoulli(0.5) {
                mutated[at] = mutated[at].wrapping_add(1 + rng.below(255) as u8);
            } else {
                mutated.truncate(at);
            }
            let _ = InferRequest::decode(&mutated); // Ok or Err, no panic
        }
    });
}

#[test]
fn prop_loghist_percentile_within_relative_error_envelope() {
    // For ANY sample stream and ANY p, the log-bucketed percentile is the
    // bucket lower bound of the true nearest-rank sample: it never
    // over-reads, and under-reads by at most one sub-bucket width (≤
    // 12.5% with 8 sub-buckets per octave; exact below 8).
    use flashkat::util::stats::LogHist;

    cases(40, |seed, rng| {
        let n = 1 + rng.below(500);
        let mut h = LogHist::default();
        let mut raw: Vec<u64> = (0..n)
            .map(|_| {
                // Wide log-range values: anything from sub-octave to ~2^64.
                rng.next_u64() >> rng.below(60)
            })
            .collect();
        for &v in &raw {
            h.record(v);
        }
        raw.sort_unstable();
        for p in [0.0, 1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
            let exact = raw[rank - 1];
            let got = h.percentile(p);
            assert!(got.is_finite(), "seed {seed} p={p}");
            let got = got as u64;
            assert!(got <= exact, "seed {seed} p={p}: {got} over-reads exact {exact}");
            assert!(
                exact - got <= exact / 8,
                "seed {seed} p={p}: {got} under-reads {exact} beyond one sub-bucket"
            );
            if exact < 8 {
                assert_eq!(got, exact, "seed {seed} p={p}: sub-octave values are exact");
            }
        }
    });
}

#[test]
fn prop_loghist_merge_is_order_independent() {
    // merge is element-wise counter addition, so ANY partition of a
    // stream into shards, merged in ANY order, must reproduce the
    // histogram of the whole stream — counts, sums, buckets, and every
    // percentile (this is what makes the per-shard `/metrics` aggregation
    // sound).
    use flashkat::util::stats::LogHist;

    cases(30, |seed, rng| {
        let n = 1 + rng.below(300);
        let shards = 1 + rng.below(5);
        let samples: Vec<u64> = (0..n).map(|_| rng.next_u64() >> rng.below(60)).collect();
        let mut whole = LogHist::default();
        let mut parts = vec![LogHist::default(); shards];
        for &v in &samples {
            whole.record(v);
            parts[rng.below(shards)].record(v);
        }
        // Forward merge order vs reverse merge order vs the unsharded
        // histogram: all three identical.
        let mut fwd = LogHist::default();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = LogHist::default();
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        assert_eq!(fwd, rev, "seed {seed}: merge order changed the histogram");
        assert_eq!(fwd, whole, "seed {seed}: sharded merge != unsharded record");
        assert_eq!(fwd.count(), n as u64, "seed {seed}");
        assert_eq!(fwd.sum(), whole.sum(), "seed {seed}");
        assert_eq!(fwd.cumulative_buckets(), whole.cumulative_buckets(), "seed {seed}");
        for p in [50.0, 95.0, 99.0] {
            let (a, b) = (fwd.percentile(p), whole.percentile(p));
            assert!(a == b || (a.is_nan() && b.is_nan()), "seed {seed} p={p}: {a} vs {b}");
        }
    });
}

#[test]
fn prop_cached_runs_partition_counters_under_any_dup_mix() {
    // For ANY duplication ratio × shard count × cache budget (from
    // "everything fits" down to "constant eviction"), a cached run
    // serves the whole workload error-free and the cache's accounting
    // holds: every request probed exactly once, each probe bumped
    // exactly one of hits/misses/coalesced, the misses are exactly the
    // requests the executors saw (the singleflight guarantee — no
    // duplicate in-flight execution ever reached a batcher), the byte
    // ledger respects capacity, and no flight outlives its leader.
    use flashkat::serve::{loadgen, BatchPolicy, LoadConfig, ModelSpec};

    cases(8, |seed, rng| {
        let dup_frac = [0.0, 0.25, 0.5, 0.9][rng.below(4)];
        let cfg = LoadConfig {
            requests: 40 + rng.below(60),
            concurrency: 1 + rng.below(8),
            seed: seed * 97 + 3,
            dup_frac,
            models: vec![ModelSpec::new("a", 32, 4), ModelSpec::new("b", 64, 8)],
            ..Default::default()
        };
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(16),
            deadline_us: [0, 100, 5_000][rng.below(3)],
            queue_depth: 4 + rng.below(60),
            eager: rng.bernoulli(0.5),
        };
        let shards = 1 + rng.below(2);
        let cache_bytes = [1 << 20, 16 << 10, 2 << 10][rng.below(3)];
        let (res, cache) =
            loadgen::run_sharded_cached(&cfg, policy, "prop-cache", shards, cache_bytes).unwrap();
        assert_eq!(res.errors, 0, "seed {seed}");
        let cs = cache.expect("a positive budget attaches a cache");
        assert_eq!(cs.total.requests(), cfg.requests as u64, "seed {seed}: one probe per request");
        assert_eq!(
            cs.total.hits + cs.total.misses + cs.total.coalesced,
            cfg.requests as u64,
            "seed {seed}: partition"
        );
        assert_eq!(
            cs.total.misses as usize,
            res.exec.requests,
            "seed {seed}: misses are exactly the executor submissions"
        );
        assert!(cs.bytes <= cs.capacity_bytes, "seed {seed}: {} > {}", cs.bytes, cs.capacity_bytes);
        assert!(cs.total.inserts >= cs.total.evictions, "seed {seed}: evicted the never-inserted");
        assert_eq!(cs.in_flight, 0, "seed {seed}: flight leaked past shutdown");
        if dup_frac >= 0.5 {
            assert!(
                cs.total.hits + cs.total.coalesced > 0,
                "seed {seed}: a duplicate-heavy workload must repeat at least once: {cs:?}"
            );
        }
    });
}

#[test]
fn prop_traced_runs_span_every_request_uniquely_under_any_policy() {
    // For ANY batching policy (size-triggered, deadline-coalesced, eager
    // or not, single- or multi-shard): a traced run serves every request,
    // the dump holds exactly one request slice per request, span ids are
    // globally unique, and every slice's phase marks nest in admission
    // order (admit <= queue wait + batch formation <= exec start; exec +
    // reply partition the slice exactly).
    use flashkat::serve::{loadgen, BatchPolicy, LoadConfig, ModelSpec};
    use flashkat::trace::{AnnValue, TraceCollector};
    use std::sync::Arc;

    cases(6, |seed, rng| {
        let cfg = LoadConfig {
            requests: 40 + rng.below(40),
            concurrency: 1 + rng.below(8),
            seed: seed * 31 + 5,
            models: vec![ModelSpec::new("a", 32, 4), ModelSpec::new("b", 64, 8)],
            ..Default::default()
        };
        let policy = BatchPolicy {
            max_batch: 1 + rng.below(16),
            deadline_us: [0, 100, 5_000][rng.below(3)],
            queue_depth: 4 + rng.below(60),
            eager: rng.bernoulli(0.5),
        };
        let shards = 1 + rng.below(2);
        let tracer = Arc::new(TraceCollector::new());
        let res =
            loadgen::run_sharded_traced(&cfg, policy, "prop", shards, tracer.clone()).unwrap();
        assert_eq!(res.errors, 0, "seed {seed}");
        assert_eq!(res.exec.requests, cfg.requests, "seed {seed}");

        let ann = |ev: &flashkat::trace::TraceEvent, name: &str| -> u64 {
            ev.args
                .iter()
                .find_map(|(k, v)| match v {
                    AnnValue::U64(n) if *k == name => Some(*n),
                    _ => None,
                })
                .unwrap_or_else(|| panic!("seed {seed}: {:?} lacks {name:?}", ev.name))
        };
        let mut ids = Vec::new();
        for (track, events) in tracer.snapshot() {
            if !track.ends_with(" req") {
                continue;
            }
            for ev in &events {
                ids.push(ann(ev, "span_id"));
                let admit = ann(ev, "admit_us");
                assert!(
                    admit + ann(ev, "queue_wait_us") + ann(ev, "batch_form_us") <= ev.t0_us,
                    "seed {seed}: phases overrun exec start: {ev:?}"
                );
                assert_eq!(
                    ev.t0_us + ann(ev, "exec_us") + ann(ev, "reply_us"),
                    ev.t1_us,
                    "seed {seed}: exec + reply must partition the slice: {ev:?}"
                );
            }
        }
        assert_eq!(ids.len(), cfg.requests, "seed {seed}: one slice per request");
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), cfg.requests, "seed {seed}: span ids collided");
    });
}
