//! End-to-end: the flashwire binary frontend over a **sharded** serve
//! engine.
//!
//! Acceptance properties (ISSUE 5):
//! - responses over loopback flashwire are **f32 bit-identical** to
//!   in-process `Server::submit` for the same requests, across a mixed
//!   multi-model registry on ≥2 shards, under concurrent load;
//! - a saturated admission queue surfaces as a typed `QueueFull` error
//!   frame carrying a retry-after-millis hint — never a hang, never a
//!   dropped response: **every** request is answered;
//! - protocol abuse (unknown models, bad shapes, non-finite inputs,
//!   garbage frames, oversized frames) maps to typed error codes and
//!   the server keeps serving.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;
use flashkat::rational::Coeffs;
use flashkat::serve::{BatchPolicy, ModelExecutor, RationalExecutor, Server};
use flashkat::util::rng::Pcg64;
use flashkat::wire::{
    ErrCode, MsgType, WireClient, WireError, WireLimits, WireOptions, WireServer, HEADER_LEN,
};

const D_WIDE: usize = 96;
const D_NARROW: usize = 32;

fn registry(seed: u64) -> Vec<Box<dyn ModelExecutor>> {
    let mut rng = Pcg64::new(seed);
    let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
    vec![
        Box::new(RationalExecutor::new("wide", D_WIDE, cw).unwrap()),
        Box::new(RationalExecutor::new("narrow", D_NARROW, cn).unwrap()),
    ]
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The headline acceptance test: concurrent mixed-model traffic over a
/// 2-shard flashwire server, every response compared **bit for bit**
/// (`f32::to_bits`, not `==`) against an identically-seeded in-process
/// server answering the same requests.
#[test]
fn wire_responses_bit_identical_to_in_process_submit() {
    let seed = 4321;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let served = Server::start_sharded(
        registry(seed),
        BatchPolicy { max_batch: 8, deadline_us: 400, queue_depth: 128, eager: true },
        2,
    )
    .unwrap();
    assert_eq!(served.shards(), 2);
    let wire =
        WireServer::bind("127.0.0.1:0", Arc::new(served), WireOptions::default()).unwrap();
    let addr = wire.local_addr();

    let clients = 6u64;
    let reqs_each = 12u64;
    std::thread::scope(|s| {
        for client in 0..clients {
            let oracle = &oracle;
            s.spawn(move || {
                let mut conn = WireClient::connect(addr).expect("connect");
                for i in 0..reqs_each {
                    let mut rng = Pcg64::with_stream(seed, client * 1000 + i);
                    let (name, idx, d) = if (client + i) % 2 == 0 {
                        ("wide", 0u32, D_WIDE)
                    } else {
                        ("narrow", 1u32, D_NARROW)
                    };
                    let rows = 1 + rng.below(3) as u32;
                    let x: Vec<f32> =
                        (0..rows as usize * d).map(|_| rng.normal_f32()).collect();
                    let want =
                        oracle.submit_at(idx, x.clone(), rows).expect("oracle submit").y;
                    let resp = conn
                        .infer(name, &x, rows)
                        .expect("wire transport")
                        .expect("wire request served");
                    assert_eq!(
                        bits(&resp.y),
                        bits(&want),
                        "client {client} req {i} ({name}): flashwire != in-process"
                    );
                    assert!(resp.batch_size >= 1);
                }
            });
        }
    });

    let stats = wire.shutdown().expect("stats");
    let total = stats.total();
    let n = (clients * reqs_each) as usize;
    assert_eq!(total.requests, n);
    assert_eq!(total.failed, 0);
    // Per-model split sums exactly to the totals, counter by counter.
    assert_eq!(stats.per_model.len(), 2);
    let req_sum: usize = stats.per_model.iter().map(|m| m.stats.requests).sum();
    let row_sum: usize = stats.per_model.iter().map(|m| m.stats.rows).sum();
    let batch_sum: usize = stats.per_model.iter().map(|m| m.stats.batches).sum();
    assert_eq!(req_sum, total.requests);
    assert_eq!(row_sum, total.rows);
    assert_eq!(batch_sum, total.batches);
    assert_eq!(stats.model("wide").unwrap().stats.requests, n / 2);
    assert_eq!(stats.model("narrow").unwrap().stats.requests, n / 2);
    assert_eq!(stats.shard_peaks.len(), 2);
    oracle.shutdown();
}

/// An executor that blocks until released (counts entries so the test
/// can wedge the queue deterministically).
struct Gate {
    entered: Arc<AtomicUsize>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl ModelExecutor for Gate {
    fn name(&self) -> &str {
        "gated"
    }
    fn d_in(&self) -> usize {
        4
    }
    fn d_out(&self) -> usize {
        4
    }
    fn run(&mut self, x: &[f32], _rows: usize, out: &mut Vec<f32>) -> Result<()> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        let (lock, cv) = &*self.release;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        out.clear();
        out.extend_from_slice(x);
        Ok(())
    }
}

/// Saturate the admission queue behind a wedged executor: concurrent
/// wire requests must split into served-later (InferResponse after
/// release) and shed (typed `QueueFull` error frame with a nonzero
/// retry-after-millis) — with **every** request answered.
#[test]
fn saturated_queue_returns_typed_retry_after_frame_never_hangs() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let gate = Gate { entered: entered.clone(), release: release.clone() };
    let depth = 2;
    let server = Server::start(
        vec![Box::new(gate)],
        BatchPolicy { max_batch: 1, deadline_us: 100, queue_depth: depth, eager: true },
    )
    .unwrap();
    let wire = WireServer::bind(
        "127.0.0.1:0",
        Arc::new(server),
        WireOptions { conn_threads: 12, ..Default::default() },
    )
    .unwrap();
    let addr = wire.local_addr();

    // 1 wedged in the executor + `depth` queued; everything beyond that
    // must be shed as a typed QueueFull frame.
    let fired = 9usize;
    let outcomes: Vec<Result<(), WireError>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..fired {
            let release = release.clone();
            let entered = entered.clone();
            handles.push(s.spawn(move || {
                // Thread 0 wedges the executor first; the rest pile on
                // once it is provably inside `run`.
                if i > 0 {
                    while entered.load(Ordering::SeqCst) == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
                if i == fired - 1 {
                    // Last thread opens the gate after everyone else has
                    // had time to be admitted or shed.
                    std::thread::sleep(std::time::Duration::from_millis(150));
                    let (lock, cv) = &*release;
                    *lock.lock().unwrap() = true;
                    cv.notify_all();
                }
                let mut conn = WireClient::connect(addr).expect("connect");
                conn.infer("gated", &[0.5; 4], 1)
                    .expect("every request gets an answer")
                    .map(|_| ())
            }));
        }
        handles.into_iter().map(|h| h.join().expect("no hung client")).collect()
    });

    let ok = outcomes.iter().filter(|o| o.is_ok()).count();
    let shed: Vec<&WireError> = outcomes.iter().filter_map(|o| o.as_ref().err()).collect();
    assert_eq!(ok + shed.len(), fired, "only InferResponse and Error frames: {outcomes:?}");
    assert!(ok >= 1, "the wedged request itself completes after release");
    assert!(
        !shed.is_empty(),
        "a {depth}-deep queue under {fired} concurrent requests must shed"
    );
    for e in &shed {
        assert_eq!(e.code, ErrCode::QueueFull, "{e}");
        assert!(e.retry_after_millis > 0, "shed frame must carry a retry hint: {e}");
    }
    let stats = wire.shutdown().expect("stats");
    assert_eq!(stats.total().requests, ok, "every InferResponse is a served request");
    assert!(stats.peak_queued <= depth);
}

/// Protocol-level rejects: each abuse gets its typed code, and the
/// server keeps serving afterwards.
#[test]
fn malformed_traffic_gets_typed_errors_and_service_survives() {
    let server = Server::start_sharded(registry(9), BatchPolicy::default(), 2).unwrap();
    let wire = WireServer::bind(
        "127.0.0.1:0",
        Arc::new(server),
        WireOptions {
            limits: WireLimits { max_payload_bytes: 4096, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let addr = wire.local_addr();
    let mut conn = WireClient::connect(addr).unwrap();

    // Unknown model → BadModel; wrong shape → BadShape; NaN → NonFinite.
    let e = conn.infer("nope", &[0.0; 4], 1).unwrap().unwrap_err();
    assert_eq!((e.code, e.code.http_equiv()), (ErrCode::BadModel, 404));
    let e = conn.infer("wide", &[1.0; 3], 1).unwrap().unwrap_err();
    assert_eq!((e.code, e.code.http_equiv()), (ErrCode::BadShape, 400));
    let e = conn.infer("wide", &[f32::INFINITY; D_WIDE], 1).unwrap().unwrap_err();
    assert_eq!((e.code, e.code.http_equiv()), (ErrCode::NonFiniteInput, 400));
    // The connection survives message-level errors and still serves.
    let mut rng = Pcg64::new(10);
    let x: Vec<f32> = (0..D_WIDE).map(|_| rng.normal_f32()).collect();
    assert!(conn.infer("wide", &x, 1).unwrap().is_ok());

    // Oversized frame: a header declaring more than the cap is refused
    // at the header — the body was never uploaded — and the connection
    // closes.  Raw socket to hand-craft the header.
    {
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        let mut header = Vec::from(*b"FW");
        header.push(1); // version
        header.push(MsgType::InferRequest as u8);
        header.extend_from_slice(&999_999u32.to_le_bytes());
        raw.write_all(&header).unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap(); // server answers then closes
        assert!(buf.len() > HEADER_LEN);
        let err = WireError::decode(&buf[HEADER_LEN..]).unwrap();
        assert_eq!(err.code, ErrCode::BadFrame);
        assert!(err.message.contains("over the 4096 cap"), "{}", err.message);
    }

    // Garbage bytes → BadFrame, connection closed.
    {
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.write_all(b"POST /v1/models/wide/infer HTTP/1.1\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap();
        let err = WireError::decode(&buf[HEADER_LEN..]).unwrap();
        assert_eq!(err.code, ErrCode::BadFrame, "HTTP spoken at a wire port is rejected");
    }

    // The server still serves good traffic afterwards, and the binary
    // stats frame accounts for exactly the served requests.
    let mut conn = WireClient::connect(addr).unwrap();
    conn.ping(42).unwrap();
    let x: Vec<f32> = (0..2 * D_NARROW).map(|_| rng.normal_f32()).collect();
    assert!(conn.infer("narrow", &x, 2).unwrap().is_ok());
    let stats = conn.stats().unwrap();
    assert_eq!(stats.models.len(), 2);
    assert_eq!(stats.models[0].name, "wide");
    assert_eq!(stats.models[0].requests, 1);
    assert_eq!(stats.models[1].name, "narrow");
    assert_eq!(stats.models[1].requests, 1);
    assert_eq!(stats.shard_peaks.len(), 2);

    let final_stats = wire.shutdown().expect("stats");
    assert_eq!(final_stats.total().requests, 2, "only the good requests reached an executor");
    assert_eq!(final_stats.total().failed, 0);
    assert_eq!(wire.metrics().error_count(ErrCode::BadFrame), 2);
    assert_eq!(wire.metrics().error_count(ErrCode::BadModel), 1);
}
