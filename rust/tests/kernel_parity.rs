//! Fast-path / oracle parity: the monomorphized native f32/f64 kernels
//! (rational::kernel) against the generic `T: Float` round-trip reference.
//!
//! Contract (DESIGN.md §4):
//! - f64: bit-identical everywhere (the round-trip *is* native f64).
//! - f32 forward: bit-identical (every step is one rounded op in both).
//! - f32 backward: dA contributions bit-identical (pure single-product
//!   chains); dx/dB within a small per-op rounding envelope of the
//!   reference (the reference fuses some expressions into one rounding).

use flashkat::rational::accumulate::{backward, Strategy};
use flashkat::rational::{
    backward_elem, backward_elem_ref, forward_elem, forward_elem_ref, kernel, Coeffs,
};
use flashkat::util::rng::Pcg64;

fn rand_coeffs(rng: &mut Pcg64, m1: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    (
        (0..m1).map(|_| rng.normal()).collect(),
        (0..n).map(|_| rng.normal()).collect(),
    )
}

#[test]
fn f64_fast_paths_bitwise_identical_to_reference() {
    let mut rng = Pcg64::new(101);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a, b) = rand_coeffs(&mut rng, m1, n);
            let mut da_f = vec![0f64; m1];
            let mut db_f = vec![0f64; n];
            let mut da_r = vec![0f64; m1];
            let mut db_r = vec![0f64; n];
            for _ in 0..200 {
                let x = rng.normal() * 3.0;
                let dout = rng.normal();
                let yf = forward_elem(x, &a, &b);
                let yr = forward_elem_ref(x, &a, &b);
                assert_eq!(yf.to_bits(), yr.to_bits(), "fwd m1={m1} n={n} x={x}");
                let dxf = backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f);
                let dxr = backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r);
                assert_eq!(dxf.to_bits(), dxr.to_bits(), "dx m1={m1} n={n}");
                for i in 0..m1 {
                    assert_eq!(da_f[i].to_bits(), da_r[i].to_bits(), "da[{i}]");
                }
                for j in 0..n {
                    assert_eq!(db_f[j].to_bits(), db_r[j].to_bits(), "db[{j}]");
                }
            }
        }
    }
}

#[test]
fn f32_forward_and_da_bitwise_identical_to_reference() {
    let mut rng = Pcg64::new(202);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a64, b64) = rand_coeffs(&mut rng, m1, n);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let mut da_f = vec![0f32; m1];
            let mut db_f = vec![0f32; n];
            let mut da_r = vec![0f32; m1];
            let mut db_r = vec![0f32; n];
            for _ in 0..200 {
                let x = (rng.normal() * 3.0) as f32;
                let dout = rng.normal_f32();
                let yf = forward_elem(x, &a, &b);
                let yr = forward_elem_ref(x, &a, &b);
                assert_eq!(yf.to_bits(), yr.to_bits(), "fwd m1={m1} n={n} x={x}");
                backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f);
                backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r);
                for i in 0..m1 {
                    assert_eq!(
                        da_f[i].to_bits(),
                        da_r[i].to_bits(),
                        "da[{i}] m1={m1} n={n} x={x} dout={dout}"
                    );
                }
            }
        }
    }
}

/// Widened (f64) error envelope for dx from f32 inputs.  Uses the
/// absolute-value (condition) sums of the derivative polynomials rather
/// than their actual values, so the bound survives cancellation both
/// inside the Horner evaluations and between the two dx terms.  Note the
/// P/Q/sign stage is bit-identical between fast and reference paths, so
/// only the derivative chains and the final combine contribute.
fn widened_dx_envelope(x: f32, dout: f32, a: &[f32], b: &[f32]) -> f64 {
    let (m1, n) = (a.len(), b.len());
    let xe = (x as f64).abs();
    let mut p_env = 0.0;
    let mut xp = 1.0;
    for &ai in a.iter() {
        p_env += (ai as f64).abs() * xp;
        xp *= xe;
    }
    // Q >= 1 always, so every 1/Q and P/Q^2 factor is bounded by the
    // corresponding numerator envelope — Q drops out of the bound.
    let mut dp_env = 0.0;
    let mut xp = 1.0;
    for (i, &ai) in a.iter().enumerate().skip(1) {
        dp_env += (ai as f64).abs() * i as f64 * xp;
        xp *= xe;
    }
    let mut dadx_env = 0.0;
    let mut xp = 1.0;
    for (j, &bj) in b.iter().enumerate() {
        dadx_env += (bj as f64).abs() * (j + 1) as f64 * xp;
        xp *= xe;
    }
    (dout as f64).abs() * (dp_env + dadx_env * p_env)
}

#[test]
fn f32_backward_dx_db_within_fused_rounding_envelope() {
    const EPS: f64 = f32::EPSILON as f64;
    let mut rng = Pcg64::new(303);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a64, b64) = rand_coeffs(&mut rng, m1, n);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let mut da_f = vec![0f32; m1];
            let mut db_f = vec![0f32; n];
            let mut da_r = vec![0f32; m1];
            let mut db_r = vec![0f32; n];
            for _ in 0..200 {
                let x = (rng.normal() * 3.0) as f32;
                let dout = rng.normal_f32();
                let dxf = backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f) as f64;
                let dxr = backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r) as f64;
                let dx_tol = 64.0 * EPS * widened_dx_envelope(x, dout, &a, &b) + 1e-30;
                assert!(
                    (dxf - dxr).abs() <= dx_tol,
                    "dx fast {dxf} vs ref {dxr} (tol {dx_tol:.3e}) m1={m1} n={n} x={x}"
                );
                for j in 0..n {
                    let (f, r) = (db_f[j] as f64, db_r[j] as f64);
                    let tol = 16.0 * EPS * r.abs() + 1e-30;
                    assert!(
                        (f - r).abs() <= tol,
                        "db[{j}] fast {f} vs ref {r} m1={m1} n={n} x={x}"
                    );
                }
            }
        }
    }
}

#[test]
fn dx_bitwise_identical_across_strategies_random_shapes_f32() {
    // All strategies share one dispatched element kernel, so dx must be
    // bit-identical for any tiling: remainder blocks, group counts, odd
    // row counts.
    let mut rng = Pcg64::new(404);
    for case in 0..12u64 {
        let n_g = 1usize << (case % 4);
        let d_g = 1 + rng.below(24);
        let d = n_g * d_g;
        let rows = 1 + rng.below(97);
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let c = Coeffs::<f32>::randn(n_g, 6, 4, &mut rng);
        let s_block = 1 + rng.below(rows + 16);
        let (dx0, _, _) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);
        for strat in [
            Strategy::BlockTree { s_block },
            Strategy::BlockSequential { s_block },
            Strategy::PairwiseFull,
        ] {
            let (dx, _, _) = backward(&x, &dout, rows, d, &c, strat);
            for (u, v) in dx.iter().zip(&dx0) {
                assert_eq!(u.to_bits(), v.to_bits(), "case {case} {strat:?}");
            }
        }
    }
}

#[test]
fn spill_path_above_register_caps_agrees_with_sequential_f64() {
    // m1/n above the register caps exercise the heap spill twin; in f64
    // every ordering agrees to ~1e-9 relative.
    let (m1, n) = (kernel::MAX_M1 + 2, kernel::MAX_N + 1);
    let mut rng = Pcg64::new(505);
    let n_g = 2;
    let d_g = 7;
    let d = n_g * d_g;
    let rows = 53;
    let x: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let dout: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let c = Coeffs::<f64>::randn(n_g, m1, n, &mut rng);
    let (dx0, da0, db0) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);
    for strat in [
        Strategy::BlockTree { s_block: 8 },
        Strategy::BlockSequential { s_block: 5 },
    ] {
        let (dx, da, db) = backward(&x, &dout, rows, d, &c, strat);
        for (u, v) in dx.iter().zip(&dx0) {
            assert_eq!(u.to_bits(), v.to_bits(), "{strat:?}");
        }
        let scale = da0.iter().map(|v| v.abs()).fold(1.0, f64::max);
        for (u, v) in da.iter().zip(&da0) {
            assert!((u - v).abs() / scale < 1e-9, "{strat:?}");
        }
        let scale = db0.iter().map(|v| v.abs()).fold(1.0, f64::max);
        for (u, v) in db.iter().zip(&db0) {
            assert!((u - v).abs() / scale < 1e-9, "{strat:?}");
        }
    }
}

#[test]
fn register_caps_cover_the_paper_config() {
    assert!(kernel::fits_registers(6, 4));
    assert!(kernel::MAX_M1 >= 6 && kernel::MAX_N >= 4);
}

// ---- dispatched (possibly SIMD) paths vs the scalar oracle ----
//
// On a stable build the dispatched hooks ARE the scalar path, so these
// hold trivially; under `--features simd` they are the bit-exactness
// contract of DESIGN.md §14: f64 bitwise-identical, and f32 bitwise too
// (the SIMD kernel mirrors the scalar *fast path* op for op — stronger
// than the §4 envelope, which bounds fast-vs-reference, not SIMD-vs-
// scalar).  Widths 1..=33 sweep every masked-tail remainder for both
// lane counts (8 for f32, 4 for f64).

use flashkat::rational::kernel::{SegAccum, TileAcc};
use flashkat::rational::Float;

fn bits_eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

#[test]
fn dispatched_forward_seg_bitwise_matches_scalar_elem_all_widths() {
    let mut rng = Pcg64::new(606);
    for w in 1..=33usize {
        let (a64, b64) = rand_coeffs(&mut rng, 6, 4);
        let xs64: Vec<f64> = (0..w).map(|_| rng.normal() * 3.0).collect();
        let mut out64 = vec![0f64; w];
        <f64 as Float>::forward_seg_fast(&xs64, &mut out64, &a64, &b64);
        for (k, &x) in xs64.iter().enumerate() {
            assert_eq!(
                out64[k].to_bits(),
                forward_elem(x, &a64, &b64).to_bits(),
                "f64 w={w} k={k}"
            );
        }

        let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
        let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
        let xs: Vec<f32> = xs64.iter().map(|&v| v as f32).collect();
        let mut out = vec![0f32; w];
        <f32 as Float>::forward_seg_fast(&xs, &mut out, &a, &b);
        for (k, &x) in xs.iter().enumerate() {
            assert_eq!(
                out[k].to_bits(),
                forward_elem(x, &a, &b).to_bits(),
                "f32 w={w} k={k}"
            );
        }
    }
}

#[test]
fn dispatched_backward_acc_bitwise_matches_tile_acc_including_masked_tails() {
    // Multi-row segments at every width remainder: the dispatched
    // accumulator (`Float::Acc`) must reproduce the scalar TileAcc's dx
    // and dA/dB partials bit for bit — with the masked-tail elements
    // (indices past the last full lane tile) asserted separately so a
    // tail-handling regression cannot hide behind the full tiles.
    let (m1, n) = (6usize, 4usize);
    let mut rng = Pcg64::new(707);
    for d_g in 1..=33usize {
        for &(rows, tree) in &[(3usize, true), (2usize, false)] {
            let (a64, b64) = rand_coeffs(&mut rng, m1, n);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let x: Vec<f32> = (0..rows * d_g).map(|_| rng.normal_f32()).collect();
            let dout: Vec<f32> = (0..rows * d_g).map(|_| rng.normal_f32()).collect();

            let mut dx_o = vec![0f32; rows * d_g];
            let mut oracle = TileAcc::<f32>::new(m1, n, tree);
            let mut dx_d = vec![0f32; rows * d_g];
            let mut disp = <<f32 as Float>::Acc as SegAccum<f32>>::new(m1, n, tree);
            for r in 0..rows {
                let s = r * d_g;
                kernel::backward_row_seg(
                    &x[s..s + d_g],
                    &dout[s..s + d_g],
                    &mut dx_o[s..s + d_g],
                    &a,
                    &b,
                    &mut oracle,
                );
                disp.row_seg(&x[s..s + d_g], &dout[s..s + d_g], &mut dx_d[s..s + d_g], &a, &b);
            }

            // Masked-tail indices first: the last d_g % LANES elements of
            // each row segment (LANES=8 covers f32; every remainder class
            // appears across d_g=1..=33).
            for lanes in [8usize, 4] {
                let tail = d_g % lanes;
                if tail > 0 {
                    for r in 0..rows {
                        let s = r * d_g + (d_g - tail);
                        for k in s..s + tail {
                            assert_eq!(
                                dx_d[k].to_bits(),
                                dx_o[k].to_bits(),
                                "tail dx d_g={d_g} lanes={lanes} k={k}"
                            );
                        }
                    }
                }
            }
            for k in 0..rows * d_g {
                assert_eq!(dx_d[k].to_bits(), dx_o[k].to_bits(), "dx d_g={d_g} k={k}");
            }
            let (da_o, db_o) = oracle.finish();
            let (da_d, db_d) = disp.finish();
            for i in 0..m1 {
                assert_eq!(da_d[i].to_bits(), da_o[i].to_bits(), "da[{i}] d_g={d_g} tree={tree}");
            }
            for j in 0..n {
                assert_eq!(db_d[j].to_bits(), db_o[j].to_bits(), "db[{j}] d_g={d_g} tree={tree}");
            }
        }
    }
}

/// The `probe` feature's acceptance contract (DESIGN.md §17): compiling
/// the traffic counters in must not perturb a single bit of any kernel
/// output, and the counters themselves must actually move.  Deltas are
/// asserted as monotone lower bounds, never exact totals — other tests
/// run concurrently and the counters are process-global.
#[cfg(feature = "probe")]
#[test]
fn probed_kernels_are_bit_identical_and_counters_advance() {
    use flashkat::probe::{self, Phase, Stream};
    use flashkat::rational::forward;

    let (rows, d, n_g) = (37usize, 48usize, 4usize);
    let mut rng = Pcg64::new(909);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let c = Coeffs::<f32>::randn(n_g, 6, 4, &mut rng);

    assert!(flashkat::probe::Snapshot::enabled());

    let base = probe::snapshot();
    let y0 = forward(&x, rows, d, &c);
    let y1 = forward(&x, rows, d, &c);
    let strat = Strategy::BlockTree { s_block: 8 };
    let (dx0, da0, db0) = backward(&x, &dout, rows, d, &c, strat);
    let (dx1, da1, db1) = backward(&x, &dout, rows, d, &c, strat);
    let delta = probe::snapshot().delta_since(&base);

    // Bit identity across repeated probed runs.
    for (u, v) in y0.iter().zip(&y1) {
        assert_eq!(u.to_bits(), v.to_bits(), "probed forward not deterministic");
    }
    for (got, want) in [(&dx0, &dx1), (&da0, &da1), (&db0, &db1)] {
        for (u, v) in got.iter().zip(want.iter()) {
            assert_eq!(u.to_bits(), v.to_bits(), "probed backward not deterministic");
        }
    }

    // The workload above logically moves at least 2 forward passes of x
    // in and y out, and 2 backward passes of x+dout in and dx out; the
    // process-global counters may only ever exceed that.
    let row_bytes = (rows * d * 4) as u64;
    assert!(delta.loaded(Phase::Forward, Stream::X) >= 2 * row_bytes, "{delta:?}");
    assert!(delta.stored(Phase::Forward, Stream::Y) >= 2 * row_bytes, "{delta:?}");
    assert!(delta.loaded(Phase::Forward, Stream::Coeffs) > 0, "{delta:?}");
    assert!(delta.loaded(Phase::Backward, Stream::X) >= 2 * row_bytes, "{delta:?}");
    assert!(delta.loaded(Phase::Backward, Stream::Dout) >= 2 * row_bytes, "{delta:?}");
    assert!(delta.stored(Phase::Backward, Stream::Dx) >= 2 * row_bytes, "{delta:?}");
    assert!(delta.stored(Phase::Reduce, Stream::Partials) > 0, "{delta:?}");
    assert!(delta.phase_bytes(Phase::Forward) > 0 && delta.phase_bytes(Phase::Backward) > 0);
    assert!(delta.total_bytes() >= delta.phase_bytes(Phase::Forward));
}

#[test]
fn dispatched_backward_acc_bitwise_matches_tile_acc_f64_tails() {
    // Same contract in f64 (lane count 4): the acceptance criterion is
    // bitwise identity for every tested width including tails.
    let (m1, n) = (6usize, 4usize);
    let mut rng = Pcg64::new(808);
    for d_g in 1..=17usize {
        let (a, b) = rand_coeffs(&mut rng, m1, n);
        let rows = 3usize;
        let x: Vec<f64> = (0..rows * d_g).map(|_| rng.normal()).collect();
        let dout: Vec<f64> = (0..rows * d_g).map(|_| rng.normal()).collect();
        let mut dx_o = vec![0f64; rows * d_g];
        let mut oracle = TileAcc::<f64>::new(m1, n, true);
        let mut dx_d = vec![0f64; rows * d_g];
        let mut disp = <<f64 as Float>::Acc as SegAccum<f64>>::new(m1, n, true);
        for r in 0..rows {
            let s = r * d_g;
            kernel::backward_row_seg(
                &x[s..s + d_g],
                &dout[s..s + d_g],
                &mut dx_o[s..s + d_g],
                &a,
                &b,
                &mut oracle,
            );
            disp.row_seg(&x[s..s + d_g], &dout[s..s + d_g], &mut dx_d[s..s + d_g], &a, &b);
        }
        for k in 0..rows * d_g {
            assert!(bits_eq_f64(dx_d[k], dx_o[k]), "dx d_g={d_g} k={k}");
        }
        let (da_o, db_o) = oracle.finish();
        let (da_d, db_d) = disp.finish();
        for i in 0..m1 {
            assert!(bits_eq_f64(da_d[i], da_o[i]), "da[{i}] d_g={d_g}");
        }
        for j in 0..n {
            assert!(bits_eq_f64(db_d[j], db_o[j]), "db[{j}] d_g={d_g}");
        }
    }
}
