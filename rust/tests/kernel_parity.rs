//! Fast-path / oracle parity: the monomorphized native f32/f64 kernels
//! (rational::kernel) against the generic `T: Float` round-trip reference.
//!
//! Contract (DESIGN.md §4):
//! - f64: bit-identical everywhere (the round-trip *is* native f64).
//! - f32 forward: bit-identical (every step is one rounded op in both).
//! - f32 backward: dA contributions bit-identical (pure single-product
//!   chains); dx/dB within a small per-op rounding envelope of the
//!   reference (the reference fuses some expressions into one rounding).

use flashkat::rational::accumulate::{backward, Strategy};
use flashkat::rational::{
    backward_elem, backward_elem_ref, forward_elem, forward_elem_ref, kernel, Coeffs,
};
use flashkat::util::rng::Pcg64;

fn rand_coeffs(rng: &mut Pcg64, m1: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    (
        (0..m1).map(|_| rng.normal()).collect(),
        (0..n).map(|_| rng.normal()).collect(),
    )
}

#[test]
fn f64_fast_paths_bitwise_identical_to_reference() {
    let mut rng = Pcg64::new(101);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a, b) = rand_coeffs(&mut rng, m1, n);
            let mut da_f = vec![0f64; m1];
            let mut db_f = vec![0f64; n];
            let mut da_r = vec![0f64; m1];
            let mut db_r = vec![0f64; n];
            for _ in 0..200 {
                let x = rng.normal() * 3.0;
                let dout = rng.normal();
                let yf = forward_elem(x, &a, &b);
                let yr = forward_elem_ref(x, &a, &b);
                assert_eq!(yf.to_bits(), yr.to_bits(), "fwd m1={m1} n={n} x={x}");
                let dxf = backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f);
                let dxr = backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r);
                assert_eq!(dxf.to_bits(), dxr.to_bits(), "dx m1={m1} n={n}");
                for i in 0..m1 {
                    assert_eq!(da_f[i].to_bits(), da_r[i].to_bits(), "da[{i}]");
                }
                for j in 0..n {
                    assert_eq!(db_f[j].to_bits(), db_r[j].to_bits(), "db[{j}]");
                }
            }
        }
    }
}

#[test]
fn f32_forward_and_da_bitwise_identical_to_reference() {
    let mut rng = Pcg64::new(202);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a64, b64) = rand_coeffs(&mut rng, m1, n);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let mut da_f = vec![0f32; m1];
            let mut db_f = vec![0f32; n];
            let mut da_r = vec![0f32; m1];
            let mut db_r = vec![0f32; n];
            for _ in 0..200 {
                let x = (rng.normal() * 3.0) as f32;
                let dout = rng.normal_f32();
                let yf = forward_elem(x, &a, &b);
                let yr = forward_elem_ref(x, &a, &b);
                assert_eq!(yf.to_bits(), yr.to_bits(), "fwd m1={m1} n={n} x={x}");
                backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f);
                backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r);
                for i in 0..m1 {
                    assert_eq!(
                        da_f[i].to_bits(),
                        da_r[i].to_bits(),
                        "da[{i}] m1={m1} n={n} x={x} dout={dout}"
                    );
                }
            }
        }
    }
}

/// Widened (f64) error envelope for dx from f32 inputs.  Uses the
/// absolute-value (condition) sums of the derivative polynomials rather
/// than their actual values, so the bound survives cancellation both
/// inside the Horner evaluations and between the two dx terms.  Note the
/// P/Q/sign stage is bit-identical between fast and reference paths, so
/// only the derivative chains and the final combine contribute.
fn widened_dx_envelope(x: f32, dout: f32, a: &[f32], b: &[f32]) -> f64 {
    let (m1, n) = (a.len(), b.len());
    let xe = (x as f64).abs();
    let mut p_env = 0.0;
    let mut xp = 1.0;
    for &ai in a.iter() {
        p_env += (ai as f64).abs() * xp;
        xp *= xe;
    }
    // Q >= 1 always, so every 1/Q and P/Q^2 factor is bounded by the
    // corresponding numerator envelope — Q drops out of the bound.
    let mut dp_env = 0.0;
    let mut xp = 1.0;
    for (i, &ai) in a.iter().enumerate().skip(1) {
        dp_env += (ai as f64).abs() * i as f64 * xp;
        xp *= xe;
    }
    let mut dadx_env = 0.0;
    let mut xp = 1.0;
    for (j, &bj) in b.iter().enumerate() {
        dadx_env += (bj as f64).abs() * (j + 1) as f64 * xp;
        xp *= xe;
    }
    (dout as f64).abs() * (dp_env + dadx_env * p_env)
}

#[test]
fn f32_backward_dx_db_within_fused_rounding_envelope() {
    const EPS: f64 = f32::EPSILON as f64;
    let mut rng = Pcg64::new(303);
    for m1 in 1..=8usize {
        for n in 1..=8usize {
            let (a64, b64) = rand_coeffs(&mut rng, m1, n);
            let a: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let mut da_f = vec![0f32; m1];
            let mut db_f = vec![0f32; n];
            let mut da_r = vec![0f32; m1];
            let mut db_r = vec![0f32; n];
            for _ in 0..200 {
                let x = (rng.normal() * 3.0) as f32;
                let dout = rng.normal_f32();
                let dxf = backward_elem(x, dout, &a, &b, &mut da_f, &mut db_f) as f64;
                let dxr = backward_elem_ref(x, dout, &a, &b, &mut da_r, &mut db_r) as f64;
                let dx_tol = 64.0 * EPS * widened_dx_envelope(x, dout, &a, &b) + 1e-30;
                assert!(
                    (dxf - dxr).abs() <= dx_tol,
                    "dx fast {dxf} vs ref {dxr} (tol {dx_tol:.3e}) m1={m1} n={n} x={x}"
                );
                for j in 0..n {
                    let (f, r) = (db_f[j] as f64, db_r[j] as f64);
                    let tol = 16.0 * EPS * r.abs() + 1e-30;
                    assert!(
                        (f - r).abs() <= tol,
                        "db[{j}] fast {f} vs ref {r} m1={m1} n={n} x={x}"
                    );
                }
            }
        }
    }
}

#[test]
fn dx_bitwise_identical_across_strategies_random_shapes_f32() {
    // All strategies share one dispatched element kernel, so dx must be
    // bit-identical for any tiling: remainder blocks, group counts, odd
    // row counts.
    let mut rng = Pcg64::new(404);
    for case in 0..12u64 {
        let n_g = 1usize << (case % 4);
        let d_g = 1 + rng.below(24);
        let d = n_g * d_g;
        let rows = 1 + rng.below(97);
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let c = Coeffs::<f32>::randn(n_g, 6, 4, &mut rng);
        let s_block = 1 + rng.below(rows + 16);
        let (dx0, _, _) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);
        for strat in [
            Strategy::BlockTree { s_block },
            Strategy::BlockSequential { s_block },
            Strategy::PairwiseFull,
        ] {
            let (dx, _, _) = backward(&x, &dout, rows, d, &c, strat);
            for (u, v) in dx.iter().zip(&dx0) {
                assert_eq!(u.to_bits(), v.to_bits(), "case {case} {strat:?}");
            }
        }
    }
}

#[test]
fn spill_path_above_register_caps_agrees_with_sequential_f64() {
    // m1/n above the register caps exercise the heap spill twin; in f64
    // every ordering agrees to ~1e-9 relative.
    let (m1, n) = (kernel::MAX_M1 + 2, kernel::MAX_N + 1);
    let mut rng = Pcg64::new(505);
    let n_g = 2;
    let d_g = 7;
    let d = n_g * d_g;
    let rows = 53;
    let x: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let dout: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let c = Coeffs::<f64>::randn(n_g, m1, n, &mut rng);
    let (dx0, da0, db0) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);
    for strat in [
        Strategy::BlockTree { s_block: 8 },
        Strategy::BlockSequential { s_block: 5 },
    ] {
        let (dx, da, db) = backward(&x, &dout, rows, d, &c, strat);
        for (u, v) in dx.iter().zip(&dx0) {
            assert_eq!(u.to_bits(), v.to_bits(), "{strat:?}");
        }
        let scale = da0.iter().map(|v| v.abs()).fold(1.0, f64::max);
        for (u, v) in da.iter().zip(&da0) {
            assert!((u - v).abs() / scale < 1e-9, "{strat:?}");
        }
        let scale = db0.iter().map(|v| v.abs()).fold(1.0, f64::max);
        for (u, v) in db.iter().zip(&db0) {
            assert!((u - v).abs() / scale < 1e-9, "{strat:?}");
        }
    }
}

#[test]
fn register_caps_cover_the_paper_config() {
    assert!(kernel::fits_registers(6, 4));
    assert!(kernel::MAX_M1 >= 6 && kernel::MAX_N >= 4);
}
