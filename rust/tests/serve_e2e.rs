//! Integration: the serve subsystem end to end — seeded workloads through
//! the threaded server, batched-vs-unbatched numeric identity, deadline
//! coalescing, and open-loop arrivals.  No artifacts required.

use flashkat::rational::{forward, Coeffs};
use flashkat::serve::{loadgen, Arrival, BatchPolicy, FlushCause, LoadConfig, Model, Server};
use flashkat::util::rng::Pcg64;

/// Fixed seed → the exact same request payloads → outputs bit-identical
/// to the unbatched oracle, no matter how the scheduler slices batches.
#[test]
fn serve_outputs_bit_identical_to_unbatched_oracle() {
    let d = 128;
    let mut rng = Pcg64::new(11);
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let server = Server::start(
        vec![Model { name: "grkan".into(), d, coeffs: coeffs.clone() }],
        BatchPolicy { max_batch: 16, deadline_us: 300, queue_depth: 128, eager: true },
    );
    std::thread::scope(|s| {
        for client in 0..8u64 {
            let server = &server;
            let coeffs = &coeffs;
            s.spawn(move || {
                for i in 0..20u64 {
                    let mut rng = Pcg64::with_stream(11, client * 1000 + i);
                    let rows = 1 + rng.below(3);
                    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                    let want = forward(&x, rows, d, coeffs);
                    let got = server.submit(0, x, rows as u32).expect("served").y;
                    assert_eq!(got, want, "client {client} req {i}");
                }
            });
        }
    });
    let stats = server.shutdown().expect("stats");
    assert_eq!(stats.requests, 160);
}

/// With a non-eager policy, concurrent clients are coalesced by the
/// deadline into multi-request batches — the amortization mechanism the
/// subsystem exists for.
#[test]
fn deadline_coalesces_concurrent_clients() {
    let cfg = LoadConfig { requests: 128, concurrency: 8, d: 64, ..Default::default() };
    let res = loadgen::run(
        &cfg,
        // Deadline generous enough that slow CI scheduling can't fragment
        // the coalescing this test is about.
        BatchPolicy { max_batch: 8, deadline_us: 20_000, queue_depth: 64, eager: false },
        "deadline",
    )
    .unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 128);
    assert!(
        res.exec.mean_batch() > 2.0,
        "deadline coalescing should batch 8 closed-loop clients, got mean {}",
        res.exec.mean_batch()
    );
    // Deadline (or terminal drain) is what released the batches, not size.
    let deadline_batches = res.exec.causes[FlushCause::Deadline.index()]
        + res.exec.causes[FlushCause::Full.index()]
        + res.exec.causes[FlushCause::Drain.index()];
    assert!(deadline_batches > 0);
    assert_eq!(res.exec.causes[FlushCause::Idle.index()], 0, "non-eager policy never idles out");
}

#[test]
fn open_loop_schedule_completes_without_errors() {
    let cfg = LoadConfig {
        requests: 200,
        concurrency: 8,
        d: 64,
        arrival: Arrival::Open { rate_rps: 20_000.0 },
        ..Default::default()
    };
    let res = loadgen::run(&cfg, BatchPolicy::default(), "open").unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 200);
    assert!(res.p50_ms <= res.p99_ms);
    assert!(res.wall_secs > 0.0 && res.throughput_rps > 0.0);
}

/// The backpressure invariant holds under a deliberately tiny queue.
#[test]
fn tiny_queue_depth_is_never_exceeded() {
    let cfg = LoadConfig { requests: 96, concurrency: 12, d: 64, ..Default::default() };
    let res = loadgen::run(
        &cfg,
        BatchPolicy { max_batch: 4, deadline_us: 100, queue_depth: 3, eager: true },
        "tiny-queue",
    )
    .unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 96);
    assert!(res.exec.peak_queued <= 3, "peak {}", res.exec.peak_queued);
}
