//! Integration: the serve subsystem end to end — seeded workloads through
//! the threaded server, batched-vs-unbatched numeric identity, routing by
//! model name across a mixed registry (rational layers + a full pipeline
//! behind the batched-rows adapter), deadline coalescing, and open-loop
//! arrivals.  No artifacts required: the pipeline model is a pure-Rust
//! `ModuleExec`, exactly the seam `runtime::LoadedModule` plugs into.

use anyhow::Result;
use flashkat::rational::{forward, Coeffs};
use flashkat::runtime::{HostTensor, ModuleExec, RowsAdapter};
use flashkat::serve::{
    loadgen, Arrival, BatchPolicy, FlushCause, LoadConfig, ModelSpec, PipelineExecutor,
    RationalExecutor, Server,
};
use flashkat::util::rng::Pcg64;

/// Fixed seed → the exact same request payloads → outputs bit-identical
/// to the unbatched oracle, no matter how the scheduler slices batches.
#[test]
fn serve_outputs_bit_identical_to_unbatched_oracle() {
    let d = 128;
    let mut rng = Pcg64::new(11);
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let server = Server::start(
        vec![Box::new(RationalExecutor::new("grkan", d, coeffs.clone()).unwrap())],
        BatchPolicy { max_batch: 16, deadline_us: 300, queue_depth: 128, eager: true },
    )
    .unwrap();
    std::thread::scope(|s| {
        for client in 0..8u64 {
            let server = &server;
            let coeffs = &coeffs;
            s.spawn(move || {
                for i in 0..20u64 {
                    let mut rng = Pcg64::with_stream(11, client * 1000 + i);
                    let rows = 1 + rng.below(3);
                    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                    let want = forward(&x, rows, d, coeffs);
                    let got = server.submit("grkan", x, rows as u32).expect("served").y;
                    assert_eq!(got, want, "client {client} req {i}");
                }
            });
        }
    });
    let stats = server.shutdown().expect("stats");
    assert_eq!(stats.total().requests, 160);
}

/// Pure-Rust pipeline model standing in for an AOT `<tag>_eval` module:
/// a fixed per-output weight vector plus a deterministic, strictly
/// row-independent map (each output row reads only its own input row),
/// which is the adapter's bit-identity contract.
struct TinyEvalModule {
    batch: usize,
    d_in: usize,
    d_out: usize,
}

impl ModuleExec for TinyEvalModule {
    fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let w = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        assert_eq!(x.len(), self.batch * self.d_in);
        let mut y = vec![0.0f32; self.batch * self.d_out];
        for r in 0..self.batch {
            let row = &x[r * self.d_in..(r + 1) * self.d_in];
            for j in 0..self.d_out {
                let a = row[j % self.d_in];
                let b = row[(j * 7 + 1) % self.d_in];
                y[r * self.d_out + j] = a * w[j] + b;
            }
        }
        Ok(vec![HostTensor::F32 { shape: vec![self.batch, self.d_out], data: y }])
    }
}

fn tiny_pipeline(batch: usize, d_in: usize, d_out: usize) -> RowsAdapter {
    let w = HostTensor::F32 {
        shape: vec![d_out],
        data: (0..d_out).map(|j| 0.5 + 0.25 * j as f32).collect(),
    };
    RowsAdapter::from_parts(
        Box::new(TinyEvalModule { batch, d_in, d_out }),
        vec![w],
        vec![batch, d_in],
        vec![batch, d_out],
    )
    .unwrap()
}

/// The acceptance scenario: two rational models with different widths
/// plus a full-pipeline model served concurrently, requests routed by
/// name, every output bit-identical to its per-request reference, and
/// the per-model `ExecStats` summing exactly to the server totals.
#[test]
fn mixed_model_traffic_is_bit_identical_per_model() {
    let (d_wide, d_narrow) = (96usize, 32usize);
    let (pipe_din, pipe_dout) = (24usize, 10usize);
    let mut rng = Pcg64::new(23);
    let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);

    // Module batch 4 on purpose: request coalescing routinely crosses
    // chunk boundaries, exercising the pad path mid-traffic.
    let server = Server::start(
        vec![
            Box::new(RationalExecutor::new("wide", d_wide, cw.clone()).unwrap()),
            Box::new(RationalExecutor::new("narrow", d_narrow, cn.clone()).unwrap()),
            Box::new(PipelineExecutor::new("kat_tiny", tiny_pipeline(4, pipe_din, pipe_dout))),
        ],
        BatchPolicy { max_batch: 8, deadline_us: 400, queue_depth: 128, eager: true },
    )
    .unwrap();
    assert_eq!(server.models().len(), 3);
    assert_eq!(server.model_index("kat_tiny"), Some(2));

    let per_kind = 3u64; // clients per model kind
    let reqs_each = 15u64;
    std::thread::scope(|s| {
        for kind in 0..3u64 {
            for client in 0..per_kind {
                let server = &server;
                let (cw, cn) = (&cw, &cn);
                s.spawn(move || {
                    // Per-thread reference adapter (execute_rows keeps
                    // scratch, so it takes &mut self); same weights as
                    // the served executor, so outputs must match bit
                    // for bit.
                    let mut reference = tiny_pipeline(4, pipe_din, pipe_dout);
                    for i in 0..reqs_each {
                        let mut rng = Pcg64::with_stream(23, kind * 10_000 + client * 100 + i);
                        let rows = 1 + rng.below(4);
                        match kind {
                            0 | 1 => {
                                let (name, d, c): (&str, usize, &Coeffs<f32>) = if kind == 0 {
                                    ("wide", d_wide, cw)
                                } else {
                                    ("narrow", d_narrow, cn)
                                };
                                let x: Vec<f32> =
                                    (0..rows * d).map(|_| rng.normal_f32()).collect();
                                let want = forward(&x, rows, d, c);
                                let got = server.submit(name, x, rows as u32).expect("served").y;
                                assert_eq!(got, want, "{name} {client}/{i}");
                            }
                            _ => {
                                let x: Vec<f32> =
                                    (0..rows * pipe_din).map(|_| rng.normal_f32()).collect();
                                let mut want = Vec::new();
                                reference.execute_rows(&x, rows, &mut want).unwrap();
                                let resp =
                                    server.submit("kat_tiny", x, rows as u32).expect("served");
                                assert_eq!(resp.y, want, "pipeline {client}/{i}");
                                assert_eq!(resp.y.len(), rows * pipe_dout);
                            }
                        }
                    }
                });
            }
        }
    });

    let stats = server.shutdown().expect("stats");
    assert_eq!(stats.per_model.len(), 3);
    let total = stats.total();
    let n_per_model = (per_kind * reqs_each) as usize;
    assert_eq!(total.requests, 3 * n_per_model);
    assert_eq!(total.failed, 0);
    for name in ["wide", "narrow", "kat_tiny"] {
        assert_eq!(stats.model(name).unwrap().stats.requests, n_per_model, "{name}");
    }
    // The per-model split sums exactly to the global totals, counter by
    // counter (requests, rows, batches, causes, histogram, busy time).
    let sum =
        |f: &dyn Fn(&flashkat::serve::ModelStats) -> usize| -> usize {
            stats.per_model.iter().map(f).sum()
        };
    assert_eq!(sum(&|m| m.stats.requests), total.requests);
    assert_eq!(sum(&|m| m.stats.rows), total.rows);
    assert_eq!(sum(&|m| m.stats.batches), total.batches);
    assert_eq!(sum(&|m| m.stats.failed), total.failed);
    for c in FlushCause::ALL {
        assert_eq!(
            sum(&|m| m.stats.causes[c.index()]),
            total.causes[c.index()],
            "{c:?} split"
        );
    }
    let hist_requests =
        |h: &[usize]| -> usize { h.iter().enumerate().map(|(size, n)| size * n).sum() };
    assert_eq!(
        stats.per_model.iter().map(|m| hist_requests(&m.stats.batch_hist)).sum::<usize>(),
        hist_requests(&total.batch_hist)
    );
    assert_eq!(hist_requests(&total.batch_hist), total.requests);
    let busy_sum: f64 = stats.per_model.iter().map(|m| m.stats.busy_secs).sum();
    assert!((busy_sum - total.busy_secs).abs() < 1e-9);
    // The pipeline model's widths flow from the adapter, not the server.
    let kat = stats.model("kat_tiny").unwrap();
    assert_eq!((kat.d_in, kat.d_out), (pipe_din, pipe_dout));
}

/// With a non-eager policy, concurrent clients are coalesced by the
/// deadline into multi-request batches — the amortization mechanism the
/// subsystem exists for.
#[test]
fn deadline_coalesces_concurrent_clients() {
    let cfg = LoadConfig {
        requests: 128,
        concurrency: 8,
        models: vec![ModelSpec::new("grkan", 64, 8)],
        ..Default::default()
    };
    let res = loadgen::run(
        &cfg,
        // Deadline generous enough that slow CI scheduling can't fragment
        // the coalescing this test is about.
        BatchPolicy { max_batch: 8, deadline_us: 20_000, queue_depth: 64, eager: false },
        "deadline",
    )
    .unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 128);
    assert!(
        res.exec.mean_batch() > 2.0,
        "deadline coalescing should batch 8 closed-loop clients, got mean {}",
        res.exec.mean_batch()
    );
    // Deadline (or terminal drain) is what released the batches, not size.
    let deadline_batches = res.exec.causes[FlushCause::Deadline.index()]
        + res.exec.causes[FlushCause::Full.index()]
        + res.exec.causes[FlushCause::Drain.index()];
    assert!(deadline_batches > 0);
    assert_eq!(res.exec.causes[FlushCause::Idle.index()], 0, "non-eager policy never idles out");
}

#[test]
fn open_loop_schedule_completes_without_errors() {
    let cfg = LoadConfig {
        requests: 200,
        concurrency: 8,
        arrival: Arrival::Open { rate_rps: 20_000.0 },
        models: vec![ModelSpec::new("grkan", 64, 8)],
        ..Default::default()
    };
    let res = loadgen::run(&cfg, BatchPolicy::default(), "open").unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 200);
    assert!(res.p50_ms <= res.p99_ms);
    assert!(res.wall_secs > 0.0 && res.throughput_rps > 0.0);
}

/// The backpressure invariant holds under a deliberately tiny queue,
/// with admissions spread across a multi-model registry.
#[test]
fn tiny_queue_depth_is_never_exceeded() {
    let cfg = LoadConfig {
        requests: 96,
        concurrency: 12,
        models: vec![ModelSpec::new("a", 64, 8), ModelSpec::new("b", 32, 8)],
        ..Default::default()
    };
    let res = loadgen::run(
        &cfg,
        BatchPolicy { max_batch: 4, deadline_us: 100, queue_depth: 3, eager: true },
        "tiny-queue",
    )
    .unwrap();
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 96);
    assert!(res.peak_queued <= 3, "peak {}", res.peak_queued);
}
