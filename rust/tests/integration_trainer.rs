//! Integration: the full training coordinator over real artifacts —
//! train-step semantics, loss trajectory, checkpoint roundtrip.
//! Skips gracefully when artifacts/ hasn't been built.

use flashkat::config::TrainConfig;
use flashkat::coordinator::checkpoint::Checkpoint;
use flashkat::coordinator::Trainer;
use flashkat::runtime::Runtime;

fn artifacts() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/.stamp").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::cpu("artifacts").expect("PJRT CPU client"))
}

fn quick_cfg(tag: &str, steps: usize) -> TrainConfig {
    TrainConfig { model: tag.into(), steps, log_every: 0, ..Default::default() }
}

#[test]
fn vit_micro_short_training_reduces_loss() {
    let Some(rt) = artifacts() else { return };
    let trainer = Trainer::new(&rt, "vit_micro", quick_cfg("vit_micro", 8)).unwrap();
    let rep = trainer.train(None).unwrap();
    assert_eq!(rep.losses.len(), 8);
    assert!(rep.losses.iter().all(|l| l.is_finite()));
    assert!(
        rep.final_loss() < rep.first_loss(),
        "loss {} -> {}",
        rep.first_loss(),
        rep.final_loss()
    );
    assert!(rep.throughput_mean > 0.0);
}

#[test]
fn train_step_is_deterministic_given_state_and_seed() {
    let Some(rt) = artifacts() else { return };
    let trainer = Trainer::new(&rt, "vit_micro", quick_cfg("vit_micro", 1)).unwrap();
    let (p, m, v) = trainer.init_state().unwrap();
    let images = vec![0.1f32; trainer.batch_size() * 32 * 32 * 3];
    let labels = vec![0.1f32; trainer.batch_size() * 10];
    let (_, _, _, l1) = trainer
        .step(p.clone(), m.clone(), v.clone(), 1, 1e-3, [7, 9], images.clone(), labels.clone())
        .unwrap();
    let (_, _, _, l2) =
        trainer.step(p, m, v, 1, 1e-3, [7, 9], images, labels).unwrap();
    assert_eq!(l1, l2);
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let Some(rt) = artifacts() else { return };
    let dir = std::env::temp_dir().join(format!("fk_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.ckpt");
    let trainer = Trainer::new(&rt, "vit_micro", quick_cfg("vit_micro", 2)).unwrap();
    let rep = trainer.train(Some(&path)).unwrap();
    assert_eq!(rep.steps, 2);
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, 2);
    assert_eq!(ck.params.len(), trainer.param_leaves());
    // Leaf names follow the manifest pytree paths.
    assert!(ck.params.iter().any(|(n, _)| n.contains("blocks")));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kat_and_vit_micro_have_comparable_losses_at_init() {
    // Both models start near ln(10) on 10-way soft labels.
    let Some(rt) = artifacts() else { return };
    for tag in ["vit_micro", "kat_micro"] {
        let trainer = Trainer::new(&rt, tag, quick_cfg(tag, 1)).unwrap();
        let rep = trainer.train(None).unwrap();
        let l0 = rep.first_loss();
        assert!((1.5..4.5).contains(&l0), "{tag} initial loss {l0}");
    }
}

#[test]
fn evaluate_runs_on_initial_params() {
    let Some(rt) = artifacts() else { return };
    let trainer = Trainer::new(&rt, "vit_micro", quick_cfg("vit_micro", 1)).unwrap();
    let (p, _, _) = trainer.init_state().unwrap();
    let acc = trainer.evaluate(&p, 2).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}
