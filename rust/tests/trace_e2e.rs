//! Integration: per-request span tracing end to end (DESIGN.md §15).
//!
//! The tracing contract has three legs, all asserted here against live
//! multithreaded servers: (1) observation is free of side effects —
//! outputs with a collector attached stay bit-identical to the
//! unbatched oracle; (2) accounting is exact — every request the server
//! answered appears in the trace dump exactly once, keyed by the span
//! id the response carried; (3) the timeline is coherent — admission,
//! queue wait, batch formation, execution, and reply nest in order on
//! one shared clock, and the rendered bytes survive the same
//! packet-level scan `flashkat trace-stat` runs in CI.

use flashkat::rational::{forward, Coeffs};
use flashkat::serve::{loadgen, BatchPolicy, LoadConfig, ModelSpec, RationalExecutor, Server};
use flashkat::trace::{stat, AnnValue, TraceCollector, TraceEvent};
use flashkat::util::rng::Pcg64;
use std::sync::Arc;

/// Pull a named u64 annotation off a trace event.
fn ann(ev: &TraceEvent, name: &str) -> u64 {
    ev.args
        .iter()
        .find_map(|(k, v)| match (k, v) {
            (k, AnnValue::U64(n)) if *k == name => Some(*n),
            _ => None,
        })
        .unwrap_or_else(|| panic!("event {:?} lacks u64 annotation {name:?}", ev.name))
}

/// Every request-track event across all shards of a snapshot.
fn req_events(snapshot: &[(String, Vec<TraceEvent>)]) -> Vec<&TraceEvent> {
    snapshot
        .iter()
        .filter(|(name, _)| name.ends_with(" req"))
        .flat_map(|(_, evs)| evs.iter())
        .collect()
}

/// Tracing must observe, not perturb: a traced server's outputs stay
/// bit-identical to the unbatched oracle under concurrent multi-client
/// traffic, every response carries a span id, and the dump holds each
/// responded span exactly once with a coherent phase timeline.
#[test]
fn traced_serving_is_bit_identical_and_spans_every_request() {
    let d = 64usize;
    let mut rng = Pcg64::new(31);
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let tracer = Arc::new(TraceCollector::new());
    let server = Server::start_sharded_traced(
        vec![Box::new(RationalExecutor::new("grkan", d, coeffs.clone()).unwrap())],
        BatchPolicy { max_batch: 8, deadline_us: 300, queue_depth: 64, eager: true },
        1,
        Some(tracer.clone()),
    )
    .unwrap();

    let (clients, reqs_each) = (6u64, 15u64);
    let mut span_ids: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|client| {
                let server = &server;
                let coeffs = &coeffs;
                s.spawn(move || {
                    let mut ids = Vec::new();
                    for i in 0..reqs_each {
                        let mut rng = Pcg64::with_stream(31, client * 1000 + i);
                        let rows = 1 + rng.below(3);
                        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                        let want = forward(&x, rows, d, coeffs);
                        let resp = server.submit("grkan", x, rows as u32).expect("served");
                        assert_eq!(resp.y, want, "client {client} req {i}: traced != oracle");
                        ids.push(resp.span_id.expect("traced server sets span ids"));
                        // The phase breakdown is internally consistent on
                        // every response (u64s, so `>= 0` is structural;
                        // what matters is that exec covers a real batch).
                        let t = resp.timing;
                        assert!(
                            t.queue_wait_us < 60_000_000 && t.reply_us < 60_000_000,
                            "client {client} req {i}: wild timing {t:?}"
                        );
                    }
                    ids
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let n = (clients * reqs_each) as usize;
    span_ids.sort_unstable();
    span_ids.dedup();
    assert_eq!(span_ids.len(), n, "span ids must be unique across clients");

    let stats = server.shutdown().expect("stats");
    assert_eq!(stats.total().requests, n);

    // Exactly one request slice per responded span, and the slices
    // reconstruct the phase timeline: admit precedes exec start
    // (queue wait + batch formation fit in between), and exec + reply
    // partition the slice exactly.
    let snapshot = tracer.snapshot();
    let reqs = req_events(&snapshot);
    assert_eq!(reqs.len(), n, "one request slice per request");
    let mut traced_ids: Vec<u64> = reqs.iter().map(|ev| ann(ev, "span_id")).collect();
    traced_ids.sort_unstable();
    assert_eq!(traced_ids, span_ids, "trace dump spans = responded spans");
    for ev in &reqs {
        let admit = ann(ev, "admit_us");
        assert!(admit <= ev.t0_us, "admit {admit} after exec start {} ", ev.t0_us);
        assert!(
            admit + ann(ev, "queue_wait_us") + ann(ev, "batch_form_us") <= ev.t0_us,
            "wait phases overrun exec start: {ev:?}"
        );
        assert_eq!(
            ev.t0_us + ann(ev, "exec_us") + ann(ev, "reply_us"),
            ev.t1_us,
            "exec + reply must partition the request slice: {ev:?}"
        );
    }
    // Batch slices rode along on the shard track, annotated with cause.
    let batches: Vec<&TraceEvent> = snapshot
        .iter()
        .filter(|(name, _)| !name.ends_with(" req"))
        .flat_map(|(_, evs)| evs.iter())
        .collect();
    assert!(!batches.is_empty(), "no batch slices recorded");
    for ev in &batches {
        assert!(ev.args.iter().any(|(k, _)| *k == "cause"), "batch slice lacks cause: {ev:?}");
        assert!(ev.t0_us <= ev.t1_us);
    }

    // The rendered bytes pass the same scan `flashkat trace-stat` runs.
    let st = stat(&tracer.render()).expect("rendered trace parses");
    assert_eq!(st.slice_begins, st.slice_ends, "unbalanced slices");
    assert_eq!(st.slice_begins, n + batches.len());
    assert_eq!(tracer.dropped(), 0);
}

/// Shared harness for the two network transports: run the seeded
/// workload traced, then assert one request slice per request and at
/// least one populated handler-thread track with the given prefix.
fn assert_transport_trace(
    run: impl FnOnce(&LoadConfig, BatchPolicy, Arc<TraceCollector>) -> loadgen::BenchResult,
    handler_prefix: &str,
) {
    let cfg = LoadConfig {
        requests: 80,
        concurrency: 8,
        models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 32, 8)],
        ..Default::default()
    };
    let policy = BatchPolicy { max_batch: 8, deadline_us: 200, queue_depth: 64, eager: true };
    let tracer = Arc::new(TraceCollector::new());
    let res = run(&cfg, policy, tracer.clone());
    assert_eq!(res.errors, 0);
    assert_eq!(res.exec.requests, 80);

    let snapshot = tracer.snapshot();
    let reqs = req_events(&snapshot);
    assert_eq!(reqs.len(), 80, "one request slice per request over {handler_prefix}");
    let mut ids: Vec<u64> = reqs.iter().map(|ev| ann(ev, "span_id")).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 80, "span ids unique over {handler_prefix}");

    // Handler threads got their own tracks, and the infer traffic landed
    // on them (each handler slice carries the span it answered).
    let handler_events: Vec<&TraceEvent> = snapshot
        .iter()
        .filter(|(name, _)| name.starts_with(handler_prefix))
        .flat_map(|(_, evs)| evs.iter())
        .collect();
    assert!(
        !handler_events.is_empty(),
        "no {handler_prefix}* handler slices recorded"
    );
    let spanned = handler_events
        .iter()
        .filter(|ev| ev.args.iter().any(|(k, _)| *k == "span_id"))
        .count();
    assert!(spanned >= 80, "handler slices carry spans: {spanned} < 80");

    let st = stat(&tracer.render()).expect("rendered trace parses");
    assert_eq!(st.slice_begins, st.slice_ends);
    assert!(st.track_descriptors > 3, "shard + request + handler tracks expected");
}

/// Counter tracks (DESIGN.md §17): a traced sharded run samples every
/// shard's queue depth and cumulative traffic bytes as Perfetto COUNTER
/// tracks, the rendered bytes carry them as counter packets, and the
/// per-track scan `flashkat trace-stat` uses sees every named track.
#[test]
fn traced_sharded_run_emits_counter_tracks_per_shard() {
    use flashkat::trace::stat_by_track;

    let shards = 2usize;
    let cfg = LoadConfig {
        requests: 60,
        concurrency: 6,
        models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 32, 8)],
        ..Default::default()
    };
    let policy = BatchPolicy { max_batch: 8, deadline_us: 200, queue_depth: 64, eager: true };
    let tracer = Arc::new(TraceCollector::new());
    let res = loadgen::run_sharded_traced(&cfg, policy, "counters", shards, tracer.clone())
        .unwrap();
    assert_eq!(res.errors, 0);

    // ≥1 counter track per shard, each with ≥1 sample; traffic samples
    // are cumulative, so they must be non-decreasing in time.
    let counters = tracer.counters_snapshot();
    for s in 0..shards {
        for kind in ["queue", "traffic bytes"] {
            let name = format!("shard {s} {kind}");
            let (_, samples) = counters
                .iter()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("missing counter track {name:?}: {counters:?}"));
            assert!(!samples.is_empty(), "{name}: no samples");
            if kind == "traffic bytes" {
                let mut sorted = samples.clone();
                sorted.sort_by_key(|&(t, _)| t);
                for w in sorted.windows(2) {
                    assert!(w[1].1 >= w[0].1, "{name}: cumulative counter decreased");
                }
                assert!(sorted.last().unwrap().1 > 0, "{name}: no traffic counted");
            }
        }
    }
    let total_samples: usize = counters.iter().map(|(_, s)| s.len()).sum();

    // The rendered file round-trips: counter packets are counted by the
    // same scan `flashkat trace-stat` runs, and the per-track split sees
    // every slice and counter track by name.
    let bytes = tracer.render();
    let st = stat(&bytes).expect("rendered trace parses");
    assert_eq!(st.counters as usize, total_samples, "one counter packet per sample");
    assert!(st.counters > 0);
    assert_eq!(st.slice_begins, st.slice_ends);

    let by_track = stat_by_track(&bytes).expect("per-track scan parses");
    for s in 0..shards {
        for kind in ["queue", "traffic bytes"] {
            let name = format!("shard {s} {kind}");
            let (_, events) = by_track
                .iter()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("{name:?} missing from stat_by_track: {by_track:?}"));
            assert!(*events > 0, "{name}: counter track rendered no events");
        }
    }
    assert_eq!(tracer.dropped(), 0);
}

#[test]
fn traced_http_leg_records_request_and_handler_slices() {
    assert_transport_trace(
        |cfg, policy, tracer| {
            loadgen::run_http_traced(cfg, policy, "http-traced", 2, Some(tracer)).unwrap()
        },
        "http-",
    );
}

#[test]
fn traced_wire_leg_records_request_and_handler_slices() {
    assert_transport_trace(
        |cfg, policy, tracer| {
            loadgen::run_wire_traced(cfg, policy, "wire-traced", 2, Some(tracer)).unwrap()
        },
        "wire-",
    );
}
