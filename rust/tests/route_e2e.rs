//! End-to-end: the flashroute tier in front of real `serve-wire`
//! backends (in-process [`WireServer`]s over identically-seeded
//! replicated registries).
//!
//! Acceptance properties (ISSUE 10):
//! - responses through the router are **f32 bit-identical** to an
//!   in-process oracle, across a mixed multi-model registry on 2
//!   replicas, under concurrent load — and a binary stats request
//!   through the router returns the merged tier view (per-model
//!   counters summed, shard axes concatenated);
//! - killing one backend mid-workload loses **zero** requests: the
//!   failover path observes the dead node as a transport failure,
//!   opens its circuit, and every request in both phases is answered
//!   exactly once, bit-identically — verified by summing the two
//!   nodes' executor totals;
//! - the `--policy least-loaded` alternative serves the same bits;
//! - HTTP and flashwire share the ONE front port via protocol
//!   sniffing: `/healthz`, a routed JSON infer, and the
//!   `flashkat_route_*` Prometheus families all answer on the same
//!   address the binary protocol uses.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

use flashkat::rational::Coeffs;
use flashkat::route::{HealthState, RouteOptions, RoutePolicy, RouteServer};
use flashkat::serve::{BatchPolicy, ModelExecutor, RationalExecutor, Server};
use flashkat::util::json::Json;
use flashkat::util::rng::Pcg64;
use flashkat::wire::{WireClient, WireOptions, WireServer};

const D_WIDE: usize = 96;
const D_NARROW: usize = 32;

fn registry(seed: u64) -> Vec<Box<dyn ModelExecutor>> {
    let mut rng = Pcg64::new(seed);
    let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
    vec![
        Box::new(RationalExecutor::new("wide", D_WIDE, cw).unwrap()),
        Box::new(RationalExecutor::new("narrow", D_NARROW, cn).unwrap()),
    ]
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One replica: the full registry, rebuilt from the same seed so every
/// node is bit-for-bit interchangeable — the property failover rests on.
fn spawn_backend(seed: u64, shards: usize) -> WireServer {
    let server = Server::start_sharded(
        registry(seed),
        BatchPolicy { max_batch: 8, deadline_us: 400, queue_depth: 128, eager: true },
        shards,
    )
    .unwrap();
    WireServer::bind("127.0.0.1:0", Arc::new(server), WireOptions::default()).unwrap()
}

/// The same deterministic request stream the direct-wire test uses:
/// `(seed, stream)` fully determines model choice, row count, and data.
fn request_for(seed: u64, stream: u64) -> (&'static str, u32, Vec<f32>, u32) {
    let mut rng = Pcg64::with_stream(seed, stream);
    let (name, idx, d) =
        if stream % 2 == 0 { ("wide", 0u32, D_WIDE) } else { ("narrow", 1u32, D_NARROW) };
    let rows = 1 + rng.below(3) as u32;
    let x: Vec<f32> = (0..rows as usize * d).map(|_| rng.normal_f32()).collect();
    (name, idx, x, rows)
}

/// Headline: concurrent mixed-model traffic through the router over two
/// replicas, every response compared bit for bit against an
/// identically-seeded in-process oracle, then the merged stats view.
#[test]
fn routed_responses_bit_identical_across_two_replicas() {
    let seed = 77;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let backends: Vec<WireServer> = (0..2).map(|_| spawn_backend(seed, 2)).collect();
    let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind(
        "127.0.0.1:0",
        addrs,
        RouteOptions { probe_interval: Duration::from_millis(50), ..Default::default() },
    )
    .unwrap();
    let addr = router.local_addr();

    let clients = 4u64;
    let reqs_each = 10u64;
    std::thread::scope(|s| {
        for client in 0..clients {
            let oracle = &oracle;
            s.spawn(move || {
                let mut conn = WireClient::connect(addr).expect("connect");
                for i in 0..reqs_each {
                    let (name, idx, x, rows) = request_for(seed, client * 1000 + i);
                    let want =
                        oracle.submit_at(idx, x.clone(), rows).expect("oracle submit").y;
                    let resp = conn
                        .infer(name, &x, rows)
                        .expect("wire transport")
                        .expect("routed request served");
                    assert_eq!(
                        bits(&resp.y),
                        bits(&want),
                        "client {client} req {i} ({name}): routed != in-process"
                    );
                    assert!(resp.batch_size >= 1);
                }
            });
        }
    });
    let n = clients * reqs_each;

    // A stats request through the router is the merged tier view:
    // per-model counters summed across nodes, shard axes concatenated.
    let mut conn = WireClient::connect(addr).unwrap();
    let stats = conn.stats().unwrap();
    assert_eq!(stats.models.len(), 2, "both models listed once after the merge");
    let req_sum: u64 = stats.models.iter().map(|m| m.requests).sum();
    assert_eq!(req_sum, n, "merged per-model requests cover every routed request");
    assert_eq!(stats.shard_peaks.len(), 4, "2 nodes x 2 shards");
    assert_eq!(stats.shard_loads.len(), 4, "v2 live-load axis concatenates the same way");

    // No failures anywhere: circuits stayed closed, every reply was a
    // relayed answer.
    assert!(router.backend_states().iter().all(|s| *s == HealthState::Up));
    assert_eq!(router.metrics().total_forwarded(), n);
    assert_eq!(router.metrics().total_failed(), 0);
    let drain = router.shutdown().expect("router drain stats");
    assert_eq!(drain.forwarded, n);
    assert_eq!(drain.backends, 2);

    // Exactly-once across the tier: the nodes' executor totals sum to
    // the request count — nothing dropped, nothing double-executed.
    let mut served = 0usize;
    for b in &backends {
        let s = b.shutdown().expect("backend drain stats");
        served += s.total().requests;
        assert_eq!(s.total().failed, 0);
    }
    assert_eq!(served, n as usize);
    oracle.shutdown();
}

/// The failover gate: phase 1 completes against both nodes, then one
/// node is shut down, then phase 2 runs on the same keep-alive client
/// connection.  The prober is dormant (60 s interval) and the circuit
/// opens on one strike, so the kill is observed deterministically by
/// the forwarding path itself — no probe-timing dependence.  Every
/// request in both phases must be answered exactly once,
/// bit-identically, with no client-visible error.
#[test]
fn killing_one_backend_mid_workload_loses_no_request() {
    let seed = 5150;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let backends: Vec<WireServer> = (0..2).map(|_| spawn_backend(seed, 1)).collect();
    let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind(
        "127.0.0.1:0",
        addrs,
        RouteOptions {
            probe_interval: Duration::from_secs(60),
            fail_threshold: 1,
            down_cooldown: 1000,
            ..Default::default()
        },
    )
    .unwrap();

    let mut conn = WireClient::connect(router.local_addr()).unwrap();
    let half = 20u64;
    let send = |conn: &mut WireClient, i: u64| {
        let (name, idx, x, rows) = request_for(seed, 9000 + i);
        let want = oracle.submit_at(idx, x.clone(), rows).expect("oracle submit").y;
        let resp = conn
            .infer(name, &x, rows)
            .expect("wire transport")
            .expect("request served despite the dead node");
        assert_eq!(bits(&resp.y), bits(&want), "req {i} ({name}): routed != in-process");
    };
    for i in 0..half {
        send(&mut conn, i);
    }

    // The victim is whichever node the ring actually sent more traffic
    // to, so the kill provably severs live routes.
    let m = router.metrics();
    let victim = if m.forwarded(0) >= m.forwarded(1) { 0usize } else { 1usize };
    let survivor = 1 - victim;
    assert!(m.forwarded(victim) > 0, "the victim carried phase-1 traffic");
    assert_eq!(m.total_forwarded(), half);
    let victim_stats = backends[victim].shutdown().expect("victim drains cleanly");

    for i in half..2 * half {
        send(&mut conn, i);
    }

    // The dead node surfaced as a transport failure, its circuit
    // opened, and traffic moved — never a lost or duplicated request.
    assert!(m.failed(victim) >= 1, "the first post-kill forward must fail over");
    assert_eq!(m.failed(survivor), 0, "the survivor never failed");
    assert!(m.total_retried() >= 1, "failovers are what serve-bench reports");
    assert_eq!(router.backend_states()[victim], HealthState::Down);
    assert_eq!(router.backend_states()[survivor], HealthState::Up);
    assert_eq!(m.total_forwarded(), 2 * half, "every request got a relayed answer");

    let drain = router.shutdown().expect("router drain stats");
    assert_eq!(drain.forwarded, 2 * half);
    let survivor_stats = backends[survivor].shutdown().expect("survivor drains cleanly");

    // Exactly-once accounting: the two executor totals cover every
    // request between them, with no duplicates and no failures.
    assert_eq!(
        victim_stats.total().requests + survivor_stats.total().requests,
        2 * half as usize,
        "each request executed on exactly one node"
    );
    assert_eq!(victim_stats.total().failed + survivor_stats.total().failed, 0);
    assert!(
        survivor_stats.total().requests >= half as usize,
        "all of phase 2 landed on the survivor"
    );
    oracle.shutdown();
}

/// `--policy least-loaded` routes by live queue depth (sampled by the
/// prober) with ring order as the tiebreak — and serves the same bits.
#[test]
fn least_loaded_policy_serves_the_same_bits() {
    let seed = 31;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let backends: Vec<WireServer> = (0..2).map(|_| spawn_backend(seed, 1)).collect();
    let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind(
        "127.0.0.1:0",
        addrs,
        RouteOptions {
            policy: RoutePolicy::LeastLoaded,
            probe_interval: Duration::from_millis(25),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = router.local_addr();

    let clients = 3u64;
    let reqs_each = 10u64;
    std::thread::scope(|s| {
        for client in 0..clients {
            let oracle = &oracle;
            s.spawn(move || {
                let mut conn = WireClient::connect(addr).expect("connect");
                for i in 0..reqs_each {
                    let (name, idx, x, rows) = request_for(seed, 70_000 + client * 1000 + i);
                    let want =
                        oracle.submit_at(idx, x.clone(), rows).expect("oracle submit").y;
                    let resp = conn
                        .infer(name, &x, rows)
                        .expect("wire transport")
                        .expect("least-loaded request served");
                    assert_eq!(bits(&resp.y), bits(&want), "client {client} req {i} ({name})");
                }
            });
        }
    });

    let n = clients * reqs_each;
    let drain = router.shutdown().expect("router drain stats");
    assert_eq!(drain.forwarded, n);
    assert_eq!(drain.failed, 0);
    let served: usize =
        backends.iter().map(|b| b.shutdown().expect("drain").total().requests).sum();
    assert_eq!(served, n as usize);
    oracle.shutdown();
}

fn http_roundtrip(addr: SocketAddr, request: String) -> (String, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let sep = buf.find("\r\n\r\n").expect("header/body separator");
    (buf[..sep].to_string(), buf[sep + 4..].to_string())
}

fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    http_roundtrip(
        addr,
        format!("GET {path} HTTP/1.1\r\nHost: router\r\nConnection: close\r\n\r\n"),
    )
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> (String, String) {
    http_roundtrip(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: router\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Protocol sniffing: the SAME front port serves the flashwire binary
/// protocol and HTTP, distinguished by the first two bytes.  The HTTP
/// infer reply must carry the oracle's exact f32 bits (f32 → shortest
/// decimal → f64 → f32 round-trips exactly), and `/metrics` must expose
/// the `flashkat_route_*` families.
#[test]
fn http_and_flashwire_share_the_front_port() {
    let seed = 8;
    let oracle = Server::start(registry(seed), BatchPolicy::default()).unwrap();
    let backends: Vec<WireServer> = (0..2).map(|_| spawn_backend(seed, 1)).collect();
    let addrs: Vec<SocketAddr> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind(
        "127.0.0.1:0",
        addrs,
        RouteOptions { probe_interval: Duration::from_millis(50), ..Default::default() },
    )
    .unwrap();
    let addr = router.local_addr();

    // Binary side: ping answered by the router itself, then an infer.
    let mut conn = WireClient::connect(addr).unwrap();
    conn.ping(7).unwrap();
    let mut rng = Pcg64::new(3);
    let x: Vec<f32> = (0..2 * D_NARROW).map(|_| rng.normal_f32()).collect();
    let want = oracle.submit_at(1, x.clone(), 2).expect("oracle submit").y;
    let resp = conn.infer("narrow", &x, 2).unwrap().unwrap();
    assert_eq!(bits(&resp.y), bits(&want));

    // HTTP side, same port, raw sockets so the sniff path is what is
    // actually exercised.
    let (head, _) = http_get(addr, "/healthz");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");

    let body = Json::Obj(vec![
        (
            "x".to_string(),
            Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        ("rows".to_string(), Json::Int(2)),
    ])
    .to_string();
    let (head, reply) = http_post(addr, "/v1/models/narrow/infer", &body);
    assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{reply}");
    let v = Json::parse(&reply).expect("JSON infer reply");
    let y: Vec<f32> = v
        .get("y")
        .and_then(Json::as_arr)
        .expect("reply carries y")
        .iter()
        .map(|j| j.as_f64().expect("y is numeric") as f32)
        .collect();
    assert_eq!(bits(&y), bits(&want), "HTTP reply differs from the oracle bits");
    assert!(v.get("batch_size").and_then(Json::as_i64).expect("batch_size") >= 1);

    let (head, metrics) = http_get(addr, "/metrics");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    for family in [
        "flashkat_route_connections_total",
        "flashkat_route_forwarded_total",
        "flashkat_route_failed_total",
        "flashkat_route_retried_total",
        "flashkat_route_health_transitions_total",
        "flashkat_route_backend_up",
    ] {
        assert!(metrics.contains(family), "metrics page missing {family}:\n{metrics}");
    }

    // Unknown paths and wrong methods get typed statuses, and the
    // router keeps serving both protocols afterwards.
    let (head, _) = http_get(addr, "/nope");
    assert!(head.starts_with("HTTP/1.1 404"), "{head}");
    let (head, _) = http_get(addr, "/v1/models/narrow/infer");
    assert!(head.starts_with("HTTP/1.1 405"), "{head}");
    assert!(conn.infer("narrow", &x, 2).unwrap().is_ok());

    router.shutdown();
    for b in &backends {
        b.shutdown();
    }
    oracle.shutdown();
}
