//! Integration: AOT artifacts -> PJRT runtime -> numerics vs Rust oracle.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).
//! Tests skip gracefully when artifacts are absent so a clean checkout
//! still passes `cargo test`.

use flashkat::rational::accumulate::{backward, Strategy};
use flashkat::rational::Coeffs;
use flashkat::runtime::{HostTensor, Runtime};
use flashkat::util::rng::Pcg64;

fn artifacts() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/.stamp").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Runtime::cpu("artifacts").expect("PJRT CPU client"))
}

fn kernel_case(n_el: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Coeffs<f32>) {
    let mut rng = Pcg64::new(seed);
    let x = (0..n_el).map(|_| rng.normal_f32()).collect();
    let dout = (0..n_el).map(|_| rng.normal_f32()).collect();
    (x, dout, Coeffs::<f32>::randn(8, 6, 4, &mut rng))
}

#[test]
fn rational_fwd_artifact_matches_rust_oracle() {
    let Some(rt) = artifacts() else { return };
    let m = rt.load("rational_fwd").unwrap();
    let dims: Vec<usize> = m.manifest.raw.get("dims").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap()).collect();
    let n_el: usize = dims.iter().product();
    let (x, _, c) = kernel_case(n_el, 1);
    let outs = m
        .execute(&[
            HostTensor::F32 { shape: dims.clone(), data: x.clone() },
            HostTensor::F32 { shape: vec![8, 6], data: c.a.clone() },
            HostTensor::F32 { shape: vec![8, 4], data: c.b.clone() },
        ])
        .unwrap();
    let got = outs[0].as_f32().unwrap();
    let want = flashkat::rational::forward(&x, dims[0] * dims[1], dims[2], &c);
    let max_err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn rational_bwd_artifacts_match_oracle_and_each_other() {
    let Some(rt) = artifacts() else { return };
    let flash = rt.load("rational_bwd_flash").unwrap();
    let kat = rt.load("rational_bwd_kat").unwrap();
    let dims: Vec<usize> = flash.manifest.raw.get("dims").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap()).collect();
    let n_el: usize = dims.iter().product();
    let (x, dout, c) = kernel_case(n_el, 2);
    let inputs = [
        HostTensor::F32 { shape: dims.clone(), data: x.clone() },
        HostTensor::F32 { shape: dims.clone(), data: dout.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: c.a.clone() },
        HostTensor::F32 { shape: vec![8, 4], data: c.b.clone() },
    ];
    let of = flash.execute(&inputs).unwrap();
    let ok = kat.execute(&inputs).unwrap();

    // dX from both kernels must agree with the oracle.
    let (dx_r, da_r, _) = backward(
        &x,
        &dout,
        dims[0] * dims[1],
        dims[2],
        &c,
        Strategy::BlockTree { s_block: 128 },
    );
    let dx_scale = dx_r.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    for (label, outs) in [("flash", &of), ("kat", &ok)] {
        let dx = outs[0].as_f32().unwrap();
        let max_err =
            dx.iter().zip(&dx_r).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err / dx_scale < 1e-4, "{label} rel dX err {}", max_err / dx_scale);
    }
    // Coefficient gradients: flash vs kat agree to accumulation tolerance.
    let da_f = of[1].as_f32().unwrap();
    let da_k = ok[1].as_f32().unwrap();
    let scale = da_r.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    for (a, b) in da_f.iter().zip(da_k) {
        assert!((a - b).abs() / scale < 1e-3, "flash {a} vs kat {b}");
    }
}

#[test]
fn init_artifact_is_deterministic_and_counts_match_config() {
    let Some(rt) = artifacts() else { return };
    let m = rt.load("kat_micro_init").unwrap();
    let p1 = m.execute(&[]).unwrap();
    let p2 = m.execute(&[]).unwrap();
    let n1: usize = p1.iter().map(|t| t.elements()).sum();
    let n2: usize = p2.iter().map(|t| t.elements()).sum();
    assert_eq!(n1, n2);
    // matches the Rust config system's analytic count
    let cfg = flashkat::config::ModelConfig::preset("kat-micro").unwrap();
    assert_eq!(n1, cfg.param_count(), "init params vs analytic");
    // determinism (seed baked into the artifact)
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
}

#[test]
fn eval_artifact_runs_and_is_deterministic() {
    let Some(rt) = artifacts() else { return };
    let init = rt.load("kat_micro_init").unwrap();
    let eval = rt.load("kat_micro_eval").unwrap();
    let params = init.execute(&[]).unwrap();
    let batch = eval.manifest.meta_usize("batch").unwrap();
    let img = eval.manifest.meta_usize("img_size").unwrap();
    let mut rng = Pcg64::new(3);
    let images: Vec<f32> = (0..batch * img * img * 3).map(|_| rng.normal_f32()).collect();
    let mut inputs = params.clone();
    inputs.push(HostTensor::F32 { shape: vec![batch, img, img, 3], data: images });
    let a = eval.execute(&inputs).unwrap();
    let b = eval.execute(&inputs).unwrap();
    assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    assert!(a[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn manifest_shapes_are_enforced() {
    let Some(rt) = artifacts() else { return };
    let m = rt.load("rational_fwd").unwrap();
    // Wrong shape must be rejected before reaching XLA.
    let bad = [
        HostTensor::F32 { shape: vec![2, 2], data: vec![0.0; 4] },
        HostTensor::F32 { shape: vec![8, 6], data: vec![0.0; 48] },
        HostTensor::F32 { shape: vec![8, 4], data: vec![0.0; 32] },
    ];
    assert!(m.execute(&bad).is_err());
    // Wrong arity too.
    assert!(m.execute(&bad[..2]).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(rt) = artifacts() else { return };
    let err = match rt.load("no_such_artifact") {
        Ok(_) => panic!("load of missing artifact succeeded"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("make artifacts"), "{err}");
}
