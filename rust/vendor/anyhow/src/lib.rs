//! Minimal offline stub of the `anyhow` crate.
//!
//! Implements the exact surface this repository uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`.  Like the real crate,
//! `Error` deliberately does **not** implement `std::error::Error`, which
//! is what makes the blanket `From<E: std::error::Error>` conversion (and
//! therefore `?` on any std error) possible.

use std::fmt;

/// A type-erased error: a display message plus an optional chain of
/// context frames (most recent first, like anyhow's `{:#}` rendering).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context frame, mirroring anyhow's `context` rendering.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: context.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_and_context() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().starts_with("reading config: "));
    }

    #[test]
    fn macros_and_option_context() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("value {x} bad, want {}", 4);
        assert_eq!(e.to_string(), "value 3 bad, want 4");
        let none: Option<u32> = None;
        let e = none.context("missing slot").unwrap_err();
        assert_eq!(e.to_string(), "missing slot");
        fn bails() -> Result<()> {
            bail!("stop {}", 7);
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop 7");
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("frame {}", 1)).unwrap_err();
        assert!(e.to_string().starts_with("frame 1: "));
    }
}
