//! Minimal offline stub of the PJRT `xla` bindings.
//!
//! [`Literal`] is fully functional on the host (construction, reshape,
//! readback, tuple decomposition) so the marshalling layer and its tests
//! work unchanged.  The client / compile / execute entry points return a
//! clean "PJRT unavailable" error: in this offline build there is no XLA
//! runtime, and every caller already has an artifacts-missing skip path
//! that this error feeds into.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (offline stub build — see rust/vendor/README.md)"
    ))
}

// ---------------- literals ----------------

#[derive(Clone, Debug, PartialEq)]
enum LitData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal: flat data plus dimensions (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: LitData,
}

/// Element types a [`Literal`] can be built from / read back into.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LitStorage;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

/// Opaque storage wrapper so `NativeType` impls stay in this crate.
pub struct LitStorage(LitData);

macro_rules! native_type {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            fn wrap(data: Vec<Self>) -> LitStorage {
                LitStorage(LitData::$variant(data))
            }
            fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
                match &lit.data {
                    LitData::$variant(v) => Ok(v.clone()),
                    other => Err(Error(format!(
                        "literal is not {}: {:?}",
                        $name,
                        std::mem::discriminant(other)
                    ))),
                }
            }
        }
    };
}

native_type!(f32, F32, "f32");
native_type!(f64, F64, "f64");
native_type!(i32, I32, "i32");
native_type!(i64, I64, "i64");
native_type!(u32, U32, "u32");

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()).0 }
    }

    /// Tuple literal (what `return_tuple=True` executables produce).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: LitData::Tuple(parts) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LitData::F32(v) => v.len(),
            LitData::F64(v) => v.len(),
            LitData::I32(v) => v.len(),
            LitData::I64(v) => v.len(),
            LitData::U32(v) => v.len(),
            LitData::Tuple(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LitData::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Read the flat data back out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LitData::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

// ---------------- client / compile / execute ----------------

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
        // scalar reshape: empty dims == one element
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![Literal::vec1(&[1u32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<u32>().unwrap(), vec![1]);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn client_is_cleanly_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT unavailable"), "{err}");
    }
}
