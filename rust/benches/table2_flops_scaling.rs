//! Bench: paper Table 2 — artificial FLOP-loop sweep (1x..8x) over the
//! group-wise rational forward and backward kernels; cycles/time must
//! stay flat because the kernels are memory/atomic-bound.
//!
//!     cargo bench --bench table2_flops_scaling [--full]
//!
//! Default batch is 256 (a few seconds); `--full` uses the paper's 1024.

mod bench_util;

use flashkat::gpusim::kernels::{RationalBwdKatKernel, RationalDims};
use flashkat::gpusim::{simulate, GpuConfig};
use flashkat::report;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let dims = RationalDims {
        batch: if full { 1024 } else { 256 },
        ..RationalDims::paper()
    };
    let cfg = GpuConfig::rtx4060ti();
    print!("{}", report::table2(&cfg, dims));

    // Verify the flatness claim numerically.
    let mut d1 = dims;
    d1.flop_loops = 1;
    let mut d8 = dims;
    d8.flop_loops = 8;
    let r1 = simulate(&cfg, &RationalBwdKatKernel::new(d1));
    let r8 = simulate(&cfg, &RationalBwdKatKernel::new(d8));
    let ratio = r8.elapsed_cycles as f64 / r1.elapsed_cycles as f64;
    println!(
        "\nbwd elapsed ratio 8x/1x FLOPs = {ratio:.4} (paper: 1.0000 — \"Cycles\" identical)"
    );
    assert!(ratio < 1.1, "backward should be FLOPs-insensitive");

    bench_util::bench("simulate kat_bwd @ B=64", 1, 3, || {
        let d = RationalDims { batch: 64, ..RationalDims::paper() };
        let _ = simulate(&cfg, &RationalBwdKatKernel::new(d));
    });
}
