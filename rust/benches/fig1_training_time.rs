//! Bench: paper Figure 1 — ViT vs KAT vs FlashKAT fwd+bwd step time for
//! the T/S/B model sizes (simulated H200, batch-scaled projection).
//!
//!     cargo bench --bench fig1_training_time

mod bench_util;

use flashkat::gpusim::model_cost::{paper_models, train_step_cost};
use flashkat::gpusim::GpuConfig;
use flashkat::report;

fn main() {
    let cfg = GpuConfig::h200();
    // The figure itself:
    print!("{}", report::fig1(&cfg, 16));

    // And the per-op breakdown for the most interesting pair (T size),
    // showing where the 10^2x gap lives (the rational bwd ops).
    for name in ["vit-t", "kat-t", "flashkat-t"] {
        let shape = paper_models().into_iter().find(|m| m.name == name).unwrap();
        let cost = train_step_cost(&cfg, &shape, 16);
        println!("\n{name}: fwd {:.1} ms, bwd {:.1} ms; top ops:", cost.fwd_secs * 1e3, cost.bwd_secs * 1e3);
        let mut ops = cost.ops.clone();
        ops.sort_by(|a, b| b.secs.partial_cmp(&a.secs).unwrap());
        for op in ops.iter().take(5) {
            println!("  {:<28} {:>9.2} ms", op.label, op.secs * 1e3);
        }
    }

    // Timing of the estimator itself (the "bench" part).
    bench_util::bench("fig1 cost model (9 models)", 1, 3, || {
        for m in paper_models() {
            let _ = train_step_cost(&cfg, &m, 8);
        }
    });
}
