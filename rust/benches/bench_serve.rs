//! Serve micro-batching bench target: the same deterministic loadgen
//! behind `flashkat serve-bench`, swept over max-batch so the
//! amortization curve (1 → 64) is visible in one run.  Runs against the
//! default single-model registry (one `RationalExecutor`); multi-model
//! and pipeline registries are exercised by `serve-bench --models` /
//! `--pipeline` and `tests/serve_e2e.rs`.  Writes `BENCH_serve.json`
//! (the max-batch 64 run vs the max-batch 1 baseline) so the
//! serving-perf trajectory is tracked across PRs like
//! `BENCH_rational.json` (DESIGN.md §§9-11).
//!
//!     cargo bench --bench bench_serve -- [--requests N] [--concurrency C]

use flashkat::cli::Args;
use flashkat::serve::{loadgen, BatchPolicy, LoadConfig};

fn main() {
    // Synthetic leading command token: Args treats the first item as the
    // command, which would otherwise swallow a leading `--requests`.
    let args = Args::parse(
        std::iter::once("bench".to_string())
            .chain(std::env::args().skip(1).filter(|a| a != "--bench")),
    )
    .expect("bench args");
    let cfg = LoadConfig {
        requests: args.flag_usize("requests", 2000).expect("--requests"),
        concurrency: args.flag_usize("concurrency", 16).expect("--concurrency"),
        ..Default::default()
    };

    let mut results = Vec::new();
    for max_batch in [1usize, 4, 16, 64] {
        let res = loadgen::run(
            &cfg,
            BatchPolicy { max_batch, ..Default::default() },
            &format!("max-batch {max_batch}"),
        )
        .expect("serve run");
        println!(
            "bench {:<24} {:>10.0} img/s  p50 {:>7.3} ms  p99 {:>7.3} ms  mean batch {:>5.1}  peak queue {:>4}",
            res.label,
            res.throughput_rps,
            res.p50_ms,
            res.p99_ms,
            res.exec.mean_batch(),
            res.peak_queued
        );
        assert_eq!(res.exec.failed, 0, "no executor failures expected in the bench");
        results.push(res);
    }

    let baseline = results.remove(0);
    let main_res = results.pop().expect("max-batch 64 run");
    let speedup = main_res.throughput_rps / baseline.throughput_rps.max(1e-9);
    let json = loadgen::bench_json(&cfg, &main_res, Some(&baseline));
    std::fs::write("BENCH_serve.json", json.to_string()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json (max-batch 64 vs 1: {speedup:.2}x)");
}
