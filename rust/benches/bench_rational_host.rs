//! Repeatable host GR-KAN kernel perf harness.
//!
//! Times forward and backward for every accumulation strategy at fixed
//! dims (the acceptance dims: rows=4096, d=768, 8 groups, f32 — plus an
//! f64 row) and writes `BENCH_rational.json` so the perf trajectory is
//! tracked across PRs.
//!
//! Two baselines quantify the restructured kernel (DESIGN.md §§4, 7, 9):
//! - **seed impl**: a faithful copy of the seed's `backward_block` —
//!   scoped thread spawns per call, per-element heap scratch, f64
//!   round-trip element math, dx tile materialize+scatter.  The
//!   `speedup_block_tree_vs_seed` field is the acceptance metric (≥3x).
//! - **round-trip elem math**: the current tiled/pooled structure but
//!   with a `Scalar` that has no native fast paths, isolating the
//!   monomorphized native-precision win from the structural wins.
//!
//!     cargo bench --bench bench_rational_host -- [--rows N] [--reps N]

mod bench_util;

use flashkat::rational::accumulate::{backward, PairwiseAcc, Strategy};
use flashkat::rational::kernel::{self, TileAcc};
use flashkat::rational::{backward_elem_ref, forward_elem, Coeffs, Float};
use flashkat::tensor::Scalar;
use flashkat::util::json::Json;
use flashkat::util::parallel::{default_threads, par_chunks_mut, par_map, SendPtr};
use flashkat::util::rng::Pcg64;

// ---------------- seed implementation (frozen copy) ----------------

/// The seed's scoped-spawn parallel map (one thread batch per call).
fn seed_par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = default_threads().min(n);
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    struct SendPtr<T>(*mut T);
    unsafe impl<T> Send for SendPtr<T> {}
    unsafe impl<T> Sync for SendPtr<T> {}
    let slots: Vec<_> = out.iter_mut().map(|s| SendPtr(s as *mut Option<R>)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                unsafe { slots[i].0.write(Some(r)) };
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Faithful copy of the seed's BlockTree backward (heap accumulators,
/// f64 round-trip element math via `backward_elem_ref`, dx tiles
/// materialized then scattered).
fn seed_backward_block_tree(
    x: &[f32],
    dout: &[f32],
    rows: usize,
    d: usize,
    c: &Coeffs<f32>,
    s_block: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let s_block = s_block.max(1);
    let n_blocks = rows.div_ceil(s_block);
    let jobs: Vec<(usize, usize)> =
        (0..n_blocks).flat_map(|blk| (0..n_g).map(move |g| (blk, g))).collect();

    struct Partial {
        blk: usize,
        g: usize,
        da: Vec<f32>,
        db: Vec<f32>,
        dx: Vec<f32>,
    }

    let partials: Vec<Partial> = seed_par_map(&jobs, |&(blk, g)| {
        let a = c.a_row(g);
        let b = c.b_row(g);
        let r0 = blk * s_block;
        let r1 = (r0 + s_block).min(rows);
        let mut dx_tile = Vec::with_capacity((r1 - r0) * d_g);
        let mut da_e = vec![0f32; m1];
        let mut db_e = vec![0f32; n];
        let mut tree_a: Vec<PairwiseAcc<f32>> = vec![PairwiseAcc::default(); m1];
        let mut tree_b: Vec<PairwiseAcc<f32>> = vec![PairwiseAcc::default(); n];
        let mut seq_a = vec![0f32; m1];
        let mut seq_b = vec![0f32; n];
        const RUN: usize = 64;
        let mut run = 0usize;
        for r in r0..r1 {
            for k in 0..d_g {
                let idx = r * d + g * d_g + k;
                let dxv = backward_elem_ref(x[idx], dout[idx], a, b, &mut da_e, &mut db_e);
                dx_tile.push(dxv);
                for i in 0..m1 {
                    seq_a[i] = f32::from_f64(seq_a[i].to_f64() + da_e[i].to_f64());
                }
                for j in 0..n {
                    seq_b[j] = f32::from_f64(seq_b[j].to_f64() + db_e[j].to_f64());
                }
                run += 1;
                if run == RUN {
                    for i in 0..m1 {
                        tree_a[i].push(seq_a[i]);
                        seq_a[i] = 0.0;
                    }
                    for j in 0..n {
                        tree_b[j].push(seq_b[j]);
                        seq_b[j] = 0.0;
                    }
                    run = 0;
                }
            }
        }
        if run > 0 {
            for i in 0..m1 {
                tree_a[i].push(seq_a[i]);
            }
            for j in 0..n {
                tree_b[j].push(seq_b[j]);
            }
        }
        Partial {
            blk,
            g,
            da: tree_a.iter().map(PairwiseAcc::finish).collect(),
            db: tree_b.iter().map(PairwiseAcc::finish).collect(),
            dx: dx_tile,
        }
    });

    let mut dx = vec![0f32; x.len()];
    let mut da = vec![0f32; n_g * m1];
    let mut db = vec![0f32; n_g * n];
    for p in &partials {
        let r0 = p.blk * s_block;
        let r1 = (r0 + s_block).min(rows);
        for (t, r) in (r0..r1).enumerate() {
            let src = &p.dx[t * d_g..(t + 1) * d_g];
            dx[r * d + p.g * d_g..r * d + (p.g + 1) * d_g].copy_from_slice(src);
        }
    }
    let mut ordered: Vec<&Partial> = partials.iter().collect();
    ordered.sort_by_key(|p| (p.g, p.blk));
    for p in ordered {
        for i in 0..m1 {
            da[p.g * m1 + i] = f32::from_f64(da[p.g * m1 + i].to_f64() + p.da[i].to_f64());
        }
        for j in 0..n {
            db[p.g * n + j] = f32::from_f64(db[p.g * n + j].to_f64() + p.db[j].to_f64());
        }
    }
    (dx, da, db)
}

// -------- round-trip scalar (no native fast paths) --------

/// f32 twin without the `Float` fast-path overrides: same bits, same
/// semantics, but every op goes through the generic f64 round-trip —
/// isolates the native-math win on the current structure.
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
struct RtF32(f32);

impl Scalar for RtF32 {
    fn from_f64(x: f64) -> Self {
        RtF32(x as f32)
    }
    fn to_f64(self) -> f64 {
        self.0 as f64
    }
    const ZERO: Self = RtF32(0.0);
    const ONE: Self = RtF32(1.0);
}

impl Float for RtF32 {
    type Acc = TileAcc<RtF32>;

    fn abs(self) -> Self {
        RtF32(self.0.abs())
    }
    fn signum0(self) -> Self {
        RtF32(if self.0 > 0.0 {
            1.0
        } else if self.0 < 0.0 {
            -1.0
        } else {
            0.0
        })
    }
    fn mul_add2(self, a: Self, b: Self) -> Self {
        RtF32(self.0 * a.0 + b.0)
    }
}

// -------- scalar-forced variants (bypass the `simd` dispatch) --------
//
// Under `--features simd` the library's forward/backward dispatch to the
// lane-parallel kernel through `Float::Acc` / `forward_seg_fast`.  These
// twins pin the scalar oracle path through public APIs — per-element
// `forward_elem` (never SIMD-dispatched) and `TileAcc` +
// `backward_row_seg` in the exact structure of `backward_block`'s
// register branch — so one binary can time both variants and report the
// simd-vs-scalar ratio.  On a stable build both paths are the same code.

fn scalar_forward(x: &[f32], rows: usize, d: usize, c: &Coeffs<f32>) -> Vec<f32> {
    let d_g = d / c.n_groups;
    let mut out = vec![0f32; rows * d];
    par_chunks_mut(&mut out, d, |r, out_row| {
        let row = &x[r * d..(r + 1) * d];
        for g in 0..c.n_groups {
            let a = c.a_row(g);
            let b = c.b_row(g);
            for k in 0..d_g {
                let idx = g * d_g + k;
                out_row[idx] = forward_elem(row[idx], a, b);
            }
        }
    });
    out
}

fn scalar_backward_block_tree(
    x: &[f32],
    dout: &[f32],
    rows: usize,
    d: usize,
    c: &Coeffs<f32>,
    s_block: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let n_blocks = rows.div_ceil(s_block);
    let jobs: Vec<(usize, usize)> =
        (0..n_blocks).flat_map(|blk| (0..n_g).map(move |g| (blk, g))).collect();
    let mut dx = vec![0f32; x.len()];
    let dx_base = SendPtr(dx.as_mut_ptr());
    let partials: Vec<(usize, usize, [f32; kernel::MAX_M1], [f32; kernel::MAX_N])> =
        par_map(&jobs, |&(blk, g)| {
            let a = c.a_row(g);
            let b = c.b_row(g);
            let r0 = blk * s_block;
            let r1 = (r0 + s_block).min(rows);
            let mut acc = TileAcc::new(m1, n, true);
            for r in r0..r1 {
                let base = r * d + g * d_g;
                // SAFETY: each (blk, g) job owns a disjoint dx span and the
                // Vec outlives par_map (same pattern as accumulate.rs).
                let dx_seg =
                    unsafe { std::slice::from_raw_parts_mut(dx_base.0.add(base), d_g) };
                kernel::backward_row_seg(
                    &x[base..base + d_g],
                    &dout[base..base + d_g],
                    dx_seg,
                    a,
                    b,
                    &mut acc,
                );
            }
            let (da, db) = acc.finish();
            (blk, g, da, db)
        });
    let mut da = vec![0f32; n_g * m1];
    let mut db = vec![0f32; n_g * n];
    let mut ordered: Vec<_> = partials.iter().collect();
    ordered.sort_by_key(|&&(blk, g, _, _)| (g, blk));
    for &(_, g, pa, pb) in ordered {
        for i in 0..m1 {
            da[g * m1 + i] += pa[i];
        }
        for j in 0..n {
            db[g * n + j] += pb[j];
        }
    }
    (dx, da, db)
}

fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    // Acceptance dims: rows=4096, d=768, 8 groups (m+1=6, n=4), f32.
    let rows = arg_usize("--rows", 4096);
    let reps = arg_usize("--reps", 5);
    let d = 768;
    let (n_g, m1, n) = (8, 6, 4);
    let s_block = 128;
    let n_el = rows * d;

    let mut rng = Pcg64::new(0);
    let x: Vec<f32> = (0..n_el).map(|_| rng.normal_f32()).collect();
    let dout: Vec<f32> = (0..n_el).map(|_| rng.normal_f32()).collect();
    let coeffs = Coeffs::<f32>::randn(n_g, m1, n, &mut rng);
    let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let do64: Vec<f64> = dout.iter().map(|&v| v as f64).collect();
    let c64 = coeffs.cast::<f64>();
    let xr: Vec<RtF32> = x.iter().map(|&v| RtF32(v)).collect();
    let dor: Vec<RtF32> = dout.iter().map(|&v| RtF32(v)).collect();
    let cr = coeffs.cast::<RtF32>();

    println!(
        "host GR-KAN kernel @ rows={rows} d={d} groups={n_g} (threads={})",
        default_threads()
    );
    let mut rec = bench_util::Records::new("bench_rational_host");
    rec.meta(
        "dims",
        Json::Obj(vec![
            ("rows".into(), Json::Int(rows as i64)),
            ("d".into(), Json::Int(d as i64)),
            ("n_groups".into(), Json::Int(n_g as i64)),
            ("m1".into(), Json::Int(m1 as i64)),
            ("n".into(), Json::Int(n as i64)),
            ("s_block".into(), Json::Int(s_block as i64)),
        ]),
    );
    rec.meta("threads", Json::Int(default_threads() as i64));

    // Sanity before timing: the restructured kernel must agree with the
    // frozen seed copy (identical accumulation order; dA bit-identical,
    // dB/dx within per-element fused-rounding tolerance).
    let (dx_new, da_new, _) =
        backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block });
    let (dx_seed, da_seed, _) = seed_backward_block_tree(&x, &dout, rows, d, &coeffs, s_block);
    let da_scale = da_seed.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    for (a, b) in da_new.iter().zip(&da_seed) {
        assert!(
            (a - b).abs() / da_scale < 1e-5,
            "dA diverged from seed: {a} vs {b}"
        );
    }
    let dx_scale = dx_seed.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    for (a, b) in dx_new.iter().zip(&dx_seed) {
        assert!((a - b).abs() / dx_scale < 1e-5, "dx diverged from seed: {a} vs {b}");
    }
    drop((dx_new, da_new, dx_seed, da_seed));

    // Which variant the dispatched library paths run in this binary.
    let variant = kernel::variant();
    rec.meta("kernel_variant", Json::Str(variant.to_string()));

    // Bit-exactness gate before timing (DESIGN.md §14): the dispatched
    // forward/backward must match the scalar-forced oracle bit for bit —
    // on a simd build this is the SIMD-vs-scalar contract, on stable it
    // is trivially the same code.
    {
        let y_disp = flashkat::rational::forward(&x, rows, d, &coeffs);
        let y_scal = scalar_forward(&x, rows, d, &coeffs);
        for (k, (u, v)) in y_disp.iter().zip(&y_scal).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "forward variant mismatch at {k}");
        }
        let (dx_d, da_d, db_d) =
            backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block });
        let (dx_s, da_s, db_s) = scalar_backward_block_tree(&x, &dout, rows, d, &coeffs, s_block);
        for (k, (u, v)) in dx_d.iter().zip(&dx_s).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "dx variant mismatch at {k}");
        }
        for (k, (u, v)) in da_d.iter().zip(&da_s).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "dA variant mismatch at {k}");
        }
        for (k, (u, v)) in db_d.iter().zip(&db_s).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "dB variant mismatch at {k}");
        }
    }

    let st = bench_util::bench("fwd f32", 1, reps, || {
        let _ = flashkat::rational::forward(&x, rows, d, &coeffs);
    });
    rec.add_variant("forward_f32", variant, &st, n_el);

    let st_fwd_scalar = bench_util::bench("fwd f32 (scalar-forced)", 1, reps, || {
        let _ = scalar_forward(&x, rows, d, &coeffs);
    });
    rec.add_variant("forward_f32_scalar", "scalar", &st_fwd_scalar, n_el);

    let st_seed = bench_util::bench("bwd block-tree f32 (seed impl)", 1, reps, || {
        let _ = seed_backward_block_tree(&x, &dout, rows, d, &coeffs, s_block);
    });
    rec.add_variant("backward_f32_block_tree_seed", "seed", &st_seed, n_el);

    let st_rt = bench_util::bench("bwd block-tree f32 (round-trip elem)", 1, reps, || {
        let _ = backward(&xr, &dor, rows, d, &cr, Strategy::BlockTree { s_block });
    });
    rec.add("backward_f32_block_tree_roundtrip", &st_rt, n_el);

    let st_fast = bench_util::bench("bwd block-tree f32 (fast)", 1, reps, || {
        let _ = backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block });
    });
    rec.add_variant("backward_f32_block_tree", variant, &st_fast, n_el);

    let st_bwd_scalar = bench_util::bench("bwd block-tree f32 (scalar-forced)", 1, reps, || {
        let _ = scalar_backward_block_tree(&x, &dout, rows, d, &coeffs, s_block);
    });
    rec.add_variant("backward_f32_block_tree_scalar", "scalar", &st_bwd_scalar, n_el);

    for (label, json_label, strat) in [
        (
            "bwd block-seq f32 (fast)",
            "backward_f32_block_sequential",
            Strategy::BlockSequential { s_block },
        ),
        ("bwd sequential f32 (fast)", "backward_f32_sequential", Strategy::Sequential),
        ("bwd pairwise-full f32 (fast)", "backward_f32_pairwise_full", Strategy::PairwiseFull),
    ] {
        let st = bench_util::bench(label, 1, reps, || {
            let _ = backward(&x, &dout, rows, d, &coeffs, strat);
        });
        rec.add(json_label, &st, n_el);
    }

    let st64 = bench_util::bench("bwd block-tree f64 (fast)", 1, reps, || {
        let _ = backward(&x64, &do64, rows, d, &c64, Strategy::BlockTree { s_block });
    });
    rec.add_variant("backward_f64_block_tree", variant, &st64, n_el);

    let speedup_seed = st_seed.mean() / st_fast.mean();
    let speedup_rt = st_rt.mean() / st_fast.mean();
    rec.meta("speedup_block_tree_vs_seed", Json::Num(speedup_seed));
    rec.meta("speedup_block_tree_vs_roundtrip_elem", Json::Num(speedup_rt));
    // Dispatched-vs-scalar-forced ratio: ~1.0 on a stable (scalar) build,
    // the SIMD win under `--features simd` — the kernel-level perf datum
    // the nightly CI lane commits per run.
    let speedup_fwd = st_fwd_scalar.mean() / st.mean();
    let speedup_bwd = st_bwd_scalar.mean() / st_fast.mean();
    rec.meta("speedup_simd_vs_scalar_forward", Json::Num(speedup_fwd));
    rec.meta("speedup_simd_vs_scalar_backward", Json::Num(speedup_bwd));
    println!(
        "block-tree backward speedup: {speedup_seed:.2}x vs seed impl \
         ({speedup_rt:.2}x of it from native elem math)"
    );
    println!(
        "{variant} vs scalar-forced: forward {speedup_fwd:.2}x, backward {speedup_bwd:.2}x"
    );
    rec.write("BENCH_rational.json");
}
