//! Bench: paper Tables 5/8 — gradient rounding error of Algorithm 1 vs
//! Algorithm 2 accumulation (f32 vs f64 oracle), with the chain-length
//! scaling study that connects our CPU-scaled dims to the paper's.
//!
//!     cargo bench --bench table5_rounding [--full]

mod bench_util;

use flashkat::rational::accumulate::{backward, Strategy};
use flashkat::rational::experiment::{run, RoundingConfig};
use flashkat::rational::Coeffs;
use flashkat::report;
use flashkat::util::rng::Pcg64;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = RoundingConfig {
        rows: if full { 96 * 768 } else { 24 * 768 },
        passes: if full { 10 } else { 4 },
        ..Default::default()
    };
    print!("{}", report::table5(&cfg));

    // Chain-length scaling: the improvement ratio grows with rows toward
    // the paper's ~100x at rows = 201,728.
    println!("\nimprovement vs accumulation chain length (2 passes each):");
    for rows in [2048usize, 8192, 24 * 768] {
        let c = RoundingConfig { rows, passes: 2, ..Default::default() };
        let rep = run(&c);
        println!(
            "  rows={rows:<7} dA {:>6.1}x   dB {:>5.1}x",
            rep.improvement_da(),
            rep.improvement_db()
        );
    }

    // Hot-path timing of both accumulation strategies (the fused native
    // kernel; bench_rational_host tracks the full strategy matrix and the
    // seed-vs-restructured speedup in BENCH_rational.json).
    let rows = 8192;
    let d = 768;
    let mut rng = Pcg64::new(0);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    bench_util::bench("fwd (fused)                 8192x768", 1, 3, || {
        let _ = flashkat::rational::forward(&x, rows, d, &coeffs);
    });
    bench_util::bench("bwd sequential (Alg1 order) 8192x768", 1, 3, || {
        let _ = backward(&x, &dout, rows, d, &coeffs, Strategy::Sequential);
    });
    bench_util::bench("bwd block-tree  (Alg2)      8192x768", 1, 3, || {
        let _ = backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block: 128 });
    });
    let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let do64: Vec<f64> = dout.iter().map(|&v| v as f64).collect();
    let c64 = coeffs.cast::<f64>();
    bench_util::bench("bwd block-tree  f64 oracle  8192x768", 1, 3, || {
        let _ = backward(&x64, &do64, rows, d, &c64, Strategy::BlockTree { s_block: 128 });
    });
}
