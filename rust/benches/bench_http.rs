//! HTTP-frontend bench target: the same deterministic workload
//! in-process and over loopback HTTP, at 1 and N executor shards, so
//! the frontend's overhead and the sharding win are tracked across PRs
//! in `BENCH_http.json` like the other BENCH artifacts (DESIGN.md §12).
//!
//!     cargo bench --bench bench_http -- [--requests N] [--concurrency C] [--shards N]

use flashkat::serve::{loadgen, BatchPolicy, LoadConfig, ModelSpec};

fn main() {
    // Synthetic leading command token: Args treats the first item as the
    // command, which would otherwise swallow a leading `--requests`.
    let args = flashkat::cli::Args::parse(
        std::iter::once("bench".to_string())
            .chain(std::env::args().skip(1).filter(|a| a != "--bench")),
    )
    .expect("bench args");
    let shards = args.flag_usize("shards", 2).expect("--shards").max(1);
    let cfg = LoadConfig {
        requests: args.flag_usize("requests", 2000).expect("--requests"),
        concurrency: args.flag_usize("concurrency", 16).expect("--concurrency"),
        // Two models so sharding has something to separate.
        models: vec![ModelSpec::new("grkan", 256, 8), ModelSpec::new("small", 64, 8)],
        ..Default::default()
    };
    let policy = BatchPolicy::default();

    let row = |r: &loadgen::BenchResult| {
        println!(
            "bench {:<24} {:>10.0} img/s  p50 {:>7.3} ms  p99 {:>7.3} ms  mean batch {:>5.1}",
            r.label,
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            r.exec.mean_batch(),
        );
    };

    // Same shard count in-process and over HTTP, so the recorded
    // http_overhead isolates the transport; the 1-shard HTTP row shows
    // the sharding win on top.
    let inproc = loadgen::run_sharded(&cfg, policy, "in-process", shards).expect("in-process run");
    row(&inproc);
    let http1 = loadgen::run_http(&cfg, policy, "http-1-shard", 1).expect("http 1-shard run");
    row(&http1);
    let label = format!("http-{shards}-shards");
    let http_n = loadgen::run_http(&cfg, policy, &label, shards).expect("http sharded run");
    row(&http_n);
    assert_eq!(inproc.errors + http1.errors + http_n.errors, 0, "no request may fail");

    let json = loadgen::http_bench_json(&cfg, &inproc, &http_n, shards);
    std::fs::write("BENCH_http.json", json.to_string()).expect("write BENCH_http.json");
    println!(
        "wrote BENCH_http.json (http/{shards}-shards vs in-process throughput: {:.2}x)",
        http_n.throughput_rps / inproc.throughput_rps.max(1e-9)
    );
}
