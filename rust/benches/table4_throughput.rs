//! Bench: paper Table 4 — training throughput (images/s) for the nine
//! model variants on the simulated H200, with the paper's reference
//! numbers side by side; plus the *measured* CPU throughput of the real
//! micro-model train step through the full stack (3 steps, ±CI).
//!
//!     cargo bench --bench table4_throughput [--steps N]

mod bench_util;

use flashkat::config::TrainConfig;
use flashkat::coordinator::Trainer;
use flashkat::gpusim::GpuConfig;
use flashkat::report;
use flashkat::runtime::Runtime;

fn main() {
    print!("{}", report::table4(&GpuConfig::h200(), 16));

    if !bench_util::artifacts_available() {
        println!("(artifacts/ missing — skipping measured micro-model throughput)");
        return;
    }
    let steps: usize = std::env::args()
        .skip_while(|a| a != "--steps")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!("\nmeasured end-to-end micro-model training throughput (CPU, full stack):");
    let rt = Runtime::cpu("artifacts").expect("pjrt");
    for tag in ["vit_micro", "kat_micro"] {
        let cfg = TrainConfig { model: tag.into(), steps, log_every: 0, ..Default::default() };
        let tr = Trainer::new(&rt, tag, cfg).expect("artifacts");
        let rep = tr.train(None).expect("train");
        println!(
            "  {tag:<12} {:>8.2} (± {:.2}) img/s over {steps} steps, loss {:.3} -> {:.3}",
            rep.throughput_mean,
            rep.throughput_ci95,
            rep.first_loss(),
            rep.final_loss()
        );
    }
    println!("(CPU interpret-mode numbers validate plumbing; GPU claims live in the sim rows)");
}
