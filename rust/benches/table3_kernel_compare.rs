//! Bench: paper Table 3 + Figures 2-3 — KAT (Algorithm 1) vs FlashKAT
//! (Algorithm 2) backward kernel, on the GPU simulator at paper dims,
//! plus a CPU wall-clock sanity run of the actual AOT-compiled Pallas
//! kernels through the PJRT runtime (structure check, NOT a GPU claim).
//!
//!     cargo bench --bench table3_kernel_compare [--full]

mod bench_util;

use flashkat::gpusim::kernels::RationalDims;
use flashkat::gpusim::GpuConfig;
use flashkat::report;
use flashkat::runtime::{HostTensor, Runtime};
use flashkat::util::rng::Pcg64;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let dims = RationalDims {
        batch: if full { 1024 } else { 256 },
        ..RationalDims::paper()
    };
    let cfg = GpuConfig::rtx4060ti();
    print!("{}", report::table3(&cfg, dims));
    print!("{}", report::fig2_fig3(&cfg, dims));

    // S_block ablation (DESIGN.md §8): the access model says the atomic
    // reduction factor is S_block * d_g.
    println!("\nS_block ablation (flash bwd, simulated):");
    for s in [32u64, 64, 128, 256, 512] {
        let k = flashkat::gpusim::kernels::RationalBwdFlashKernel { dims, s_block: s };
        let r = flashkat::gpusim::simulate(&cfg, &k);
        println!(
            "  S_block={s:<4} elapsed {:>9.2} ms  atomics {}",
            r.elapsed_secs * 1e3,
            r.atomic_lanes
        );
    }

    // Host GR-KAN kernel wall-clock at the same per-row shape (the
    // restructured fused path of DESIGN.md §4; CPU substrate, so this
    // contextualizes — not reproduces — the GPU numbers above).
    {
        use flashkat::rational::accumulate::{backward, Strategy};
        use flashkat::rational::Coeffs;
        let rows = 2048;
        let d = 768;
        let mut rng = Pcg64::new(1);
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
        let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        println!("\nhost kernel wall-clock (fused, {rows}x{d}):");
        bench_util::bench("host bwd kat-order  (Alg1)", 1, 3, || {
            let _ = backward(&x, &dout, rows, d, &coeffs, Strategy::Sequential);
        });
        bench_util::bench("host bwd block-tree (Alg2)", 1, 3, || {
            let _ = backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block: 128 });
        });
    }

    if !bench_util::artifacts_available() {
        println!("\n(artifacts/ missing — skipping AOT kernel wall-clock sanity)");
        return;
    }
    let rt = Runtime::cpu("artifacts").expect("pjrt cpu");
    let flash = rt.load("rational_bwd_flash").expect("flash artifact");
    let kat = rt.load("rational_bwd_kat").expect("kat artifact");
    let d: Vec<usize> = flash.manifest.raw.get("dims").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap()).collect();
    let n_el = d.iter().product::<usize>();
    let mut rng = Pcg64::new(0);
    let x: Vec<f32> = (0..n_el).map(|_| rng.normal_f32()).collect();
    let dout: Vec<f32> = (0..n_el).map(|_| rng.normal_f32()).collect();
    let a: Vec<f32> = (0..48).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
    let inputs = [
        HostTensor::F32 { shape: d.clone(), data: x },
        HostTensor::F32 { shape: d.clone(), data: dout },
        HostTensor::F32 { shape: vec![8, 6], data: a },
        HostTensor::F32 { shape: vec![8, 4], data: b },
    ];
    println!("\nAOT kernel wall-clock on CPU PJRT (interpret-lowered; structure sanity only):");
    bench_util::bench("rational_bwd_flash (AOT, CPU)", 1, 3, || {
        let _ = flash.execute(&inputs).unwrap();
    });
    bench_util::bench("rational_bwd_kat   (AOT, CPU)", 1, 3, || {
        let _ = kat.execute(&inputs).unwrap();
    });
}
