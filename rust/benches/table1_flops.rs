//! Bench: paper Table 1 — parameter/FLOP formulas for MLP, KAN, GR-KAN.
//!
//!     cargo bench --bench table1_flops

mod bench_util;

use flashkat::flops::{self, LayerDims};
use flashkat::report;

fn main() {
    print!("{}", report::table1());

    // Sweep: GR-KAN/MLP FLOP ratio across ViT layer widths — the paper's
    // Insight-2 argument holds at every size.
    println!("\nGR-KAN : MLP flops ratio across widths");
    for d in [192usize, 384, 768, 1536] {
        let dims = LayerDims { d_in: d, d_out: 4 * d };
        let r = flops::grkan_flops(dims, 5, 4) as f64 / flops::mlp_flops(dims, 14) as f64;
        println!("  d={d:<5} ratio {r:.4}");
    }

    bench_util::bench("table1 formulas (1k evaluations)", 2, 5, || {
        let mut acc = 0u64;
        for i in 1..1000usize {
            let dims = LayerDims { d_in: i, d_out: 4 * i };
            acc = acc
                .wrapping_add(flops::grkan_flops(dims, 5, 4))
                .wrapping_add(flops::kan_flops(dims, 8, 3, 14));
        }
        std::hint::black_box(acc);
    });
}
