//! Transport bench target: the same deterministic workload in-process,
//! over loopback HTTP/JSON, and over the flashwire binary protocol —
//! all at the same shard count — tracked across PRs in
//! `BENCH_wire.json` like the other BENCH artifacts (DESIGN.md §13).
//!
//!     cargo bench --bench bench_wire -- [--requests N] [--concurrency C] [--shards N]

use flashkat::serve::{loadgen, BatchPolicy, LoadConfig, ModelSpec};

fn main() {
    // Synthetic leading command token: Args treats the first item as the
    // command, which would otherwise swallow a leading `--requests`.
    let args = flashkat::cli::Args::parse(
        std::iter::once("bench".to_string())
            .chain(std::env::args().skip(1).filter(|a| a != "--bench")),
    )
    .expect("bench args");
    let cfg = LoadConfig {
        requests: args.flag_usize("requests", 2000).expect("--requests"),
        concurrency: args.flag_usize("concurrency", 16).expect("--concurrency"),
        // Two models so sharding has something to separate; the wide one
        // is where JSON float text hurts most.
        models: vec![ModelSpec::new("grkan", 256, 8), ModelSpec::new("small", 64, 8)],
        ..Default::default()
    };
    // Clamped to the registry size, as the server clamps: the recorded
    // shard count must be the one the legs actually ran on.
    let shards = args.flag_usize("shards", 2).expect("--shards").clamp(1, cfg.models.len());
    let policy = BatchPolicy::default();

    let row = |r: &loadgen::BenchResult| {
        println!(
            "bench {:<24} {:>10.0} img/s  p50 {:>7.3} ms  p99 {:>7.3} ms  mean batch {:>5.1}",
            r.label,
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            r.exec.mean_batch(),
        );
    };

    let inproc = loadgen::run_sharded(&cfg, policy, "in-process", shards).expect("in-process run");
    row(&inproc);
    let http = loadgen::run_http(&cfg, policy, "loopback-http", shards).expect("http run");
    row(&http);
    let wire = loadgen::run_wire(&cfg, policy, "loopback-wire", shards).expect("wire run");
    row(&wire);
    assert_eq!(inproc.errors + http.errors + wire.errors, 0, "no request may fail");

    let bytes = loadgen::transport_bytes(&cfg).expect("byte accounting");
    let json = loadgen::wire_bench_json(&cfg, &inproc, &http, &wire, shards, &bytes);
    std::fs::write("BENCH_wire.json", json.to_string()).expect("write BENCH_wire.json");
    println!(
        "wrote BENCH_wire.json (wire vs json throughput: {:.2}x, bytes/request: {:.2}x)",
        wire.throughput_rps / http.throughput_rps.max(1e-9),
        bytes.wire_vs_json_ratio(),
    );
}
