//! Shared mini bench harness (offline environment: no criterion).
//!
//! Each `cargo bench` target regenerates one paper table/figure and, where
//! a hot code path is involved, reports wall-clock statistics over
//! repeated runs (mean ± 95% CI, min) in a criterion-like format.

use std::time::Instant;

use flashkat::util::stats::OnlineStats;

/// Time `f` for `reps` measured runs after `warmup` runs.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, reps: usize, mut f: F) -> OnlineStats {
    for _ in 0..warmup {
        f();
    }
    let mut st = OnlineStats::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        st.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "bench {label:<40} {:>10.3} ms (± {:.3})  min {:.3} ms  n={}",
        st.mean() * 1e3,
        st.ci95() * 1e3,
        st.min() * 1e3,
        st.count()
    );
    st
}

/// Artifacts present? Benches that need the AOT path skip gracefully.
pub fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/.stamp").exists()
}
