//! Shared mini bench harness (offline environment: no criterion).
//!
//! Each `cargo bench` target regenerates one paper table/figure and, where
//! a hot code path is involved, reports wall-clock statistics over
//! repeated runs (mean ± 95% CI, min) in a criterion-like format.
//! [`Records`] additionally persists results as JSON so the perf
//! trajectory is tracked across PRs (BENCH_rational.json).

use std::time::Instant;

use flashkat::util::json::Json;
use flashkat::util::stats::OnlineStats;

/// Time `f` for `reps` measured runs after `warmup` runs.
#[allow(dead_code)] // each bench target compiles its own copy of this module
pub fn bench<F: FnMut()>(label: &str, warmup: usize, reps: usize, mut f: F) -> OnlineStats {
    for _ in 0..warmup {
        f();
    }
    let mut st = OnlineStats::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        st.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "bench {label:<40} {:>10.3} ms (± {:.3})  min {:.3} ms  n={}",
        st.mean() * 1e3,
        st.ci95() * 1e3,
        st.min() * 1e3,
        st.count()
    );
    st
}

/// Artifacts present? Benches that need the AOT path skip gracefully.
#[allow(dead_code)]
pub fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/.stamp").exists()
}

/// Accumulates labelled timing records and writes them as one JSON file —
/// the machine-readable counterpart of [`bench`]'s stdout lines.
#[allow(dead_code)]
pub struct Records {
    bench: String,
    meta: Vec<(String, Json)>,
    results: Vec<Json>,
}

#[allow(dead_code)]
impl Records {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), meta: Vec::new(), results: Vec::new() }
    }

    /// Attach a top-level metadata field (dims, thread count, ...).
    pub fn meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Record one timed result; `elements` (if nonzero) adds a
    /// melem-per-second throughput column derived from the mean.
    pub fn add(&mut self, label: &str, st: &OnlineStats, elements: usize) {
        self.push_result(label, None, st, elements);
    }

    /// [`Records::add`] with a `variant` column — which kernel variant
    /// (`scalar`, `simd`, `seed`) produced the row, so per-variant perf
    /// is comparable across CI runs regardless of the feature flag.
    pub fn add_variant(&mut self, label: &str, variant: &str, st: &OnlineStats, elements: usize) {
        self.push_result(label, Some(variant), st, elements);
    }

    fn push_result(&mut self, label: &str, variant: Option<&str>, st: &OnlineStats, elements: usize) {
        let mut obj = vec![
            ("label".to_string(), Json::Str(label.to_string())),
            ("mean_ms".to_string(), Json::Num(st.mean() * 1e3)),
            ("ci95_ms".to_string(), Json::Num(st.ci95() * 1e3)),
            ("min_ms".to_string(), Json::Num(st.min() * 1e3)),
            ("reps".to_string(), Json::Int(st.count() as i64)),
        ];
        if let Some(v) = variant {
            obj.insert(1, ("variant".to_string(), Json::Str(v.to_string())));
        }
        if elements > 0 && st.mean() > 0.0 {
            obj.push((
                "melem_per_s".to_string(),
                Json::Num(elements as f64 / st.mean() / 1e6),
            ));
        }
        self.results.push(Json::Obj(obj));
    }

    /// Serialize to `path` (pretty enough for diffs: one top-level object).
    pub fn write(&self, path: &str) {
        let mut top = vec![("bench".to_string(), Json::Str(self.bench.clone()))];
        top.extend(self.meta.iter().cloned());
        top.push(("results".to_string(), Json::Arr(self.results.clone())));
        let text = Json::Obj(top).to_string();
        match std::fs::write(path, &text) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
