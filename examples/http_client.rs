//! Client for a running `flashkat serve-http`: submit seeded requests
//! over HTTP and verify each response is **bit-identical** to the
//! in-process forward for the same model.
//!
//! Works because both sides are deterministic: the server built its
//! registry from `(seed, model spec)` via `loadgen::executors`, and this
//! client rebuilds the identical executor locally from the same flags —
//! so any f32 mismatch means the transport (or the server) corrupted a
//! value, and the process exits nonzero.  CI uses exactly that as the
//! "200 + bit-identical payload" smoke check.
//!
//!     flashkat serve-http --port 0 --seed 7 &
//!     cargo run --release --example http_client -- --addr 127.0.0.1:PORT --seed 7

use anyhow::{bail, Context, Result};
use flashkat::cli::Args;
use flashkat::net::HttpClient;
use flashkat::serve::{loadgen, LoadConfig, ModelExecutor, ModelSpec};
use flashkat::util::json::Json;

fn main() -> Result<()> {
    // Args' grammar expects a leading command token; synthesize one so
    // `--addr ...` is parsed as a flag, not swallowed as the command.
    let args =
        Args::parse(std::iter::once("http-client".to_string()).chain(std::env::args().skip(1)))?;
    let addr: std::net::SocketAddr = args
        .flag_str("addr", "127.0.0.1:8080")
        .parse()
        .context("--addr expects host:port")?;
    let cfg = LoadConfig {
        seed: args.flag_u64("seed", 7)?,
        models: vec![ModelSpec::new(
            args.flag_str("model", "grkan"),
            args.flag_usize("d", 256)?,
            args.flag_usize("groups", 8)?.max(1),
        )],
        ..Default::default()
    };
    let requests = args.flag_u64("requests", 8)?.max(1);
    let name = cfg.models[0].name.clone();

    // The local twin of the server's executor: same seed, same spec.
    let mut reference = loadgen::executors(&cfg)?.remove(0);

    let mut client = HttpClient::connect(addr)?;
    let listing = client.get("/v1/models")?;
    if listing.status != 200 {
        bail!("GET /v1/models returned {}", listing.status);
    }
    let listing = Json::parse(&listing.body_str()).context("parsing model listing")?;
    let found = listing
        .get("models")
        .and_then(Json::as_arr)
        .map(|models| {
            models.iter().any(|m| {
                m.get("name").and_then(Json::as_str) == Some(name.as_str())
                    && m.get("d_in").and_then(Json::as_usize) == Some(cfg.models[0].d)
            })
        })
        .unwrap_or(false);
    if !found {
        bail!("server does not list model {name:?} with d_in={}", cfg.models[0].d);
    }

    for id in 0..requests {
        let (_, body) = loadgen::http_body(&cfg, id);
        let resp = client.post_json(&format!("/v1/models/{name}/infer"), &body)?;
        if resp.status != 200 {
            bail!("request {id}: status {} body {}", resp.status, resp.body_str());
        }
        let parsed = Json::parse(&resp.body_str()).context("parsing infer response")?;
        let y: Vec<f32> = parsed
            .get("y")
            .and_then(Json::as_arr)
            .context("response missing y")?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect::<Option<_>>()
            .context("non-numeric y element")?;
        let (_, rows, x) = loadgen::request(&cfg, id);
        let mut want = Vec::new();
        reference.run(&x, rows as usize, &mut want)?;
        if y != want {
            bail!("request {id}: HTTP response differs from the in-process forward");
        }
    }

    let metrics = client.get("/metrics")?;
    if metrics.status != 200 || !metrics.body_str().contains("flashkat_serve_requests_total") {
        bail!("/metrics scrape failed (status {})", metrics.status);
    }
    println!(
        "OK: {requests} responses from {addr} bit-identical to the in-process forward ({name})"
    );
    Ok(())
}
