//! Reproduce the gradient rounding-error study (paper Tables 5/8):
//! sequential atomic-order accumulation (Algorithm 1) vs block tree
//! reduction (Algorithm 2) in f32, against an f64 oracle.
//!
//!     cargo run --release --example rounding_error [rows] [passes]
//!
//! Paper dims are rows = 1024*197 = 201,728; the default here is scaled
//! for CPU wall-clock but the MAE *ratio* trend is already decisive and
//! grows with rows (see EXPERIMENTS.md).  The experiment runs passes on
//! a deterministic parallel schedule and its f64 oracle uses the
//! block-tree order (DESIGN.md §4) — in f64 the ordering difference is
//! ~1e-16 relative, far below the f32 effects reported here.

use flashkat::rational::experiment::RoundingConfig;
use flashkat::report;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32_768);
    let passes: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let cfg = RoundingConfig { rows, passes, ..Default::default() };
    print!("{}", report::table5(&cfg));
    println!("\nablation: accumulation strategies (DESIGN.md §8):");
    ablation(rows.min(16_384));

    // Extension: the paper's Appendix hypothesis — at low precision the
    // ordering benefit should matter even more for training stability.
    let lp_cfg = RoundingConfig { rows: rows.min(8_192), passes: passes.min(3), ..Default::default() };
    let (kat_b, flash_b) = flashkat::rational::experiment::run_bf16(&lp_cfg);
    println!(
        "\nbfloat16 gradients (low-precision extension, rows={}):\n  KAT dA MAE {:.3e} vs FlashKAT {:.3e} -> {:.1}x (f32 gap at same dims for comparison above)",
        lp_cfg.rows,
        kat_b.mae_mean,
        flash_b.mae_mean,
        kat_b.mae_mean / flash_b.mae_mean
    );
}

/// Strategy ablation: isolate "fewer global adds" from "tree reduction"
/// and show the best-possible full-pairwise ordering.
fn ablation(rows: usize) {
    use flashkat::rational::accumulate::{backward, Strategy};
    use flashkat::rational::Coeffs;
    use flashkat::util::rng::Pcg64;

    let d = 768;
    let mut rng = Pcg64::new(0);
    let x64: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let do64: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
    let c64 = Coeffs::<f64>::randn(8, 6, 4, &mut rng);
    let (_, da64, _) = backward(&x64, &do64, rows, d, &c64, Strategy::Sequential);

    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let do32: Vec<f32> = do64.iter().map(|&v| v as f32).collect();
    let c32 = c64.cast::<f32>();

    for (label, strat) in [
        ("sequential (Alg 1 order)", Strategy::Sequential),
        ("block tree, S=32", Strategy::BlockTree { s_block: 32 }),
        ("block tree, S=128", Strategy::BlockTree { s_block: 128 }),
        ("block tree, S=512", Strategy::BlockTree { s_block: 512 }),
        ("block sequential, S=128", Strategy::BlockSequential { s_block: 128 }),
        ("full pairwise (best case)", Strategy::PairwiseFull),
    ] {
        let (_, da, _) = backward(&x32, &do32, rows, d, &c32, strat);
        let mae: f64 = da
            .iter()
            .zip(&da64)
            .map(|(&a, &b)| (a as f64 - b).abs())
            .sum::<f64>()
            / da.len() as f64;
        println!("  {label:<28} dA MAE vs f64: {mae:.3e}");
    }
}
