//! Quickstart: load an AOT-compiled Pallas kernel and run it from Rust.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the minimal tour of the three-layer architecture: the
//! group-wise rational kernel was written in Pallas (L1), lowered through
//! a jitted JAX function (L2) into `artifacts/rational_fwd.hlo.txt`, and
//! here the Rust coordinator (L3) compiles and executes it via PJRT —
//! python is not involved at runtime.

use anyhow::{Context, Result};
use flashkat::runtime::{HostTensor, Runtime};
use flashkat::util::rng::Pcg64;

fn main() -> Result<()> {
    let rt = Runtime::cpu("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let module = rt.load("rational_fwd").context(
        "run `make artifacts` first — this example needs the AOT kernels",
    )?;
    println!(
        "loaded {} ({} inputs -> {} outputs, compiled in {:.2}s)",
        module.name,
        module.input_count(),
        module.output_count(),
        module.compile_secs
    );

    // Problem dims come from the artifact manifest.
    let dims: Vec<usize> = module.manifest.raw.get("dims").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap()).collect();
    let (b, n, d) = (dims[0], dims[1], dims[2]);
    println!("kernel dims: X in R^({b}x{n}x{d}), 8 groups, m+1=6, n=4");

    // Swish-like coefficients for every group; x ~ N(0,1).
    let mut rng = Pcg64::new(0);
    let x: Vec<f32> = (0..b * n * d).map(|_| rng.normal_f32()).collect();
    let a_row =
        [-0.0052296527f32, 0.5027744533, 0.4403392560, 0.5826427290, 0.2196305065, 0.0256087044];
    let b_row = [0.3131766296f32, 1.0135363041, 0.0271426279, 0.0494586222];
    let a: Vec<f32> = (0..8).flat_map(|_| a_row).collect();
    let bc: Vec<f32> = (0..8).flat_map(|_| b_row).collect();

    let t0 = std::time::Instant::now();
    let outs = module.execute(&[
        HostTensor::F32 { shape: vec![b, n, d], data: x.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: a },
        HostTensor::F32 { shape: vec![8, 4], data: bc },
    ])?;
    let dt = t0.elapsed();
    let y = outs[0].as_f32()?;

    // With swish coefficients, F(x) ~ silu(x).
    let mut max_dev = 0f32;
    for (xi, yi) in x.iter().zip(y).take(10_000) {
        let silu = xi / (1.0 + (-xi).exp());
        max_dev = max_dev.max((yi - silu).abs());
    }
    println!(
        "executed {} elements in {:.1} ms; max |F(x) - silu(x)| on first 10k = {:.3}",
        y.len(),
        dt.as_secs_f64() * 1e3,
        max_dev
    );
    println!("quickstart OK");
    Ok(())
}
