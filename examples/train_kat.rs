//! End-to-end validation driver (DESIGN.md §6): train KAT-micro and
//! ViT-micro through the full three-layer stack — synthetic data +
//! augmentations + cosine schedule + EMA in Rust (L3), AdamW + model
//! fwd/bwd through the Pallas rational kernels as one AOT HLO module
//! (L2/L1) — and report loss curves, throughput with 95% CIs, and
//! held-out accuracy.
//!
//!     make artifacts && cargo run --release --example train_kat [steps]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use anyhow::{Context, Result};
use flashkat::config::TrainConfig;
use flashkat::coordinator::Trainer;
use flashkat::runtime::Runtime;

fn sparkline(losses: &[f32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = losses.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = losses.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-6);
    // downsample to at most 60 columns
    let stride = losses.len().div_ceil(60).max(1);
    losses
        .chunks(stride)
        .map(|c| {
            let m = c.iter().sum::<f32>() / c.len() as f32;
            BARS[(((m - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize]
        })
        .collect()
}

fn main() -> Result<()> {
    let steps: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let rt = Runtime::cpu("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let mut rows = Vec::new();
    for tag in ["vit_micro", "kat_micro"] {
        let cfg = TrainConfig {
            model: tag.to_string(),
            steps,
            log_every: (steps / 10).max(1),
            ..Default::default()
        };
        let trainer = Trainer::new(&rt, tag, cfg)
            .context("run `make artifacts` first")?;
        println!(
            "\n== training {tag}: {} leaves, batch {}, {} steps ==",
            trainer.param_leaves(),
            trainer.batch_size(),
            steps
        );
        let ckpt = std::path::PathBuf::from(format!("/tmp/flashkat_{tag}.ckpt"));
        let rep = trainer.train(Some(&ckpt))?;
        println!("loss curve: {}", sparkline(&rep.losses));
        println!(
            "{tag}: loss {:.3} -> {:.3}, {:.2} (± {:.2}) img/s, host overhead {:.2}%, \
             held-out top-1 {:.3} (EMA {:.3}; chance 0.100), ckpt {}",
            rep.first_loss(),
            rep.final_loss(),
            rep.throughput_mean,
            rep.throughput_ci95,
            100.0 * rep.host_overhead,
            rep.final_eval_acc.unwrap_or(f64::NAN),
            rep.ema_eval_acc.unwrap_or(f64::NAN),
            ckpt.display()
        );
        rows.push((tag, rep));
    }

    println!("\n== summary (CPU, interpret-mode Pallas — speed is NOT a GPU claim) ==");
    println!("model       thp img/s (±CI)    final loss   top-1");
    for (tag, rep) in &rows {
        println!(
            "{tag:<11} {:>8.2} (±{:.2})    {:>8.3}   {:.3}",
            rep.throughput_mean,
            rep.throughput_ci95,
            rep.final_loss(),
            rep.final_eval_acc.unwrap_or(f64::NAN)
        );
    }
    println!(
        "(the paper's GPU speed comparison is reproduced by the gpusim benches;\n \
         this driver proves all three layers compose and the models learn)"
    );
    Ok(())
}
