//! Profile the KAT (Algorithm 1) vs FlashKAT (Algorithm 2) backward
//! kernels on the GPU memory-hierarchy simulator, reproducing the paper's
//! Section 3 diagnosis: Table 2 (FLOPs insensitivity), Figures 2-3
//! (warp-state statistics), and Table 3 (kernel comparison).
//!
//!     cargo run --release --example kernel_profile [batch] [gpu]

use flashkat::gpusim::kernels::RationalDims;
use flashkat::gpusim::GpuConfig;
use flashkat::report;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let batch: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let gpu = match args.get(2).map(String::as_str) {
        Some("h200") => GpuConfig::h200(),
        _ => GpuConfig::rtx4060ti(),
    };
    let dims = RationalDims { batch, ..RationalDims::paper() };
    println!(
        "simulating group-wise rational kernels at B={batch} N=197 d=768 on {} \
         (paper uses B=1024; pass a batch arg to change)",
        gpu.name
    );
    print!("{}", report::table2(&gpu, dims));
    print!("{}", report::fig2_fig3(&gpu, dims));
    print!("{}", report::table3(&gpu, dims));

    // Ablation (DESIGN.md §8): group count vs atomic contention.  More
    // groups spread Algorithm 1's atomics over more addresses — contention
    // (and the paper's whole bottleneck) scales ~1/n_g.
    println!("\nn_g ablation (Algorithm 1 backward, simulated):");
    for n_groups in [1u32, 2, 4, 8, 16, 32] {
        let mut d = dims;
        d.n_groups = n_groups;
        let r = flashkat::gpusim::simulate(
            &gpu,
            &flashkat::gpusim::kernels::RationalBwdKatKernel::new(d),
        );
        println!(
            "  n_g={n_groups:<3} elapsed {:>9.1} ms  (addresses: {})",
            r.elapsed_secs * 1e3,
            n_groups * d.coeffs_per_group()
        );
    }
}
