//! Client for a running `flashkat serve-wire`: submit seeded requests
//! over the flashwire binary protocol and verify each response is
//! **bit-identical** to the in-process forward for the same model.
//!
//! Works exactly like `examples/http_client`: the server built its
//! registry from `(seed, model spec)` via `loadgen::executors`, and
//! this client rebuilds the identical executor locally from the same
//! flags — so any f32 mismatch means the transport (or the server)
//! corrupted a value, and the process exits nonzero.  CI uses this as
//! the serve-wire "answered + bit-identical payload" smoke probe.
//!
//!     flashkat serve-wire --port 0 --seed 7 &
//!     cargo run --release --example wire_client -- --addr 127.0.0.1:PORT --seed 7

use anyhow::{bail, Context, Result};
use flashkat::cli::Args;
use flashkat::serve::{loadgen, LoadConfig, ModelExecutor, ModelSpec};
use flashkat::wire::WireClient;

fn main() -> Result<()> {
    // Args' grammar expects a leading command token; synthesize one so
    // `--addr ...` is parsed as a flag, not swallowed as the command.
    let args =
        Args::parse(std::iter::once("wire-client".to_string()).chain(std::env::args().skip(1)))?;
    let addr: std::net::SocketAddr = args
        .flag_str("addr", "127.0.0.1:8081")
        .parse()
        .context("--addr expects host:port")?;
    let cfg = LoadConfig {
        seed: args.flag_u64("seed", 7)?,
        models: vec![ModelSpec::new(
            args.flag_str("model", "grkan"),
            args.flag_usize("d", 256)?,
            args.flag_usize("groups", 8)?.max(1),
        )],
        ..Default::default()
    };
    let requests = args.flag_u64("requests", 8)?.max(1);
    let name = cfg.models[0].name.clone();

    // The local twin of the server's executor: same seed, same spec.
    let mut reference = loadgen::executors(&cfg)?.remove(0);

    // All calls go through the reconnect helper: a dropped keep-alive
    // (server restarted between probes, router failed a backend over
    // mid-conversation) heals with a capped-backoff redial instead of
    // failing the probe — the same discipline the route tier's
    // connection pool uses.
    let mut client = WireClient::connect(addr)?;
    client.call_reconnecting(3, |c| c.ping(0xf1a5_4a7)).context("ping")?;

    let mut want = Vec::new();
    for id in 0..requests {
        let (_, rows, x) = loadgen::request(&cfg, id);
        let resp = match client.call_reconnecting(3, |c| c.infer(&name, &x, rows))? {
            Ok(resp) => resp,
            Err(e) => bail!("request {id}: server answered {e}"),
        };
        reference.run(&x, rows as usize, &mut want)?;
        let got: Vec<u32> = resp.y.iter().map(|v| v.to_bits()).collect();
        let exp: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        if got != exp {
            bail!("request {id}: flashwire response differs from the in-process forward");
        }
    }

    // The binary stats frame must account for what we just sent.  When
    // the peer is a router, this is the tier-wide merged view.
    let stats = client.call_reconnecting(3, |c| c.stats()).context("stats")?;
    let served = stats
        .models
        .iter()
        .find(|m| m.name == name)
        .with_context(|| format!("server does not list model {name:?}"))?;
    if served.requests < requests {
        bail!("stats report {} requests for {name:?}, sent {requests}", served.requests);
    }
    println!(
        "OK: {requests} responses from flashwire://{addr} bit-identical to the in-process forward ({name})"
    );
    Ok(())
}
