//! Full-pipeline KAT serving demo: route requests by name to a registry
//! mixing a GR-KAN layer model with a whole-model pipeline executor.
//!
//! With AOT artifacts built (`make artifacts`), the pipeline slot serves
//! the real `kat_micro_eval` module through the PJRT runtime; without
//! them (or with the offline PJRT stub) it falls back to a pure-Rust
//! module so the example always runs — the serving stack is identical
//! either way, which is the point of the executor abstraction.
//!
//!     cargo run --example serve_pipeline

use anyhow::Result;
use flashkat::rational::Coeffs;
use flashkat::runtime::{HostTensor, ModuleExec, RowsAdapter, Runtime};
use flashkat::serve::{BatchPolicy, PipelineExecutor, RationalExecutor, Server};
use flashkat::util::rng::Pcg64;

/// Pure-Rust fallback pipeline: scales each row by a fixed weight
/// vector (row-independent, like a per-image eval model).
struct HostEval {
    batch: usize,
    d: usize,
}

impl ModuleExec for HostEval {
    fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let w = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        let y: Vec<f32> = x
            .chunks(self.d)
            .flat_map(|row| row.iter().zip(w).map(|(v, wi)| v * wi).collect::<Vec<_>>())
            .collect();
        Ok(vec![HostTensor::F32 { shape: vec![self.batch, self.d], data: y }])
    }
}

/// Real pipeline if artifacts + PJRT are available, host fallback else.
fn pipeline() -> Result<PipelineExecutor> {
    let tag = "kat_micro";
    let real = || -> Result<PipelineExecutor> {
        let rt = Runtime::cpu("artifacts")?;
        PipelineExecutor::from_runtime(&rt, tag)
    };
    match real() {
        Ok(ex) => {
            println!("pipeline model: {tag} (AOT artifact)");
            Ok(ex)
        }
        Err(e) => {
            println!("pipeline model: host fallback ({e:#})");
            let (batch, d) = (8, 48);
            let w = HostTensor::F32 {
                shape: vec![d],
                data: (0..d).map(|j| 1.0 + j as f32 / d as f32).collect(),
            };
            let adapter = RowsAdapter::from_parts(
                Box::new(HostEval { batch, d }),
                vec![w],
                vec![batch, d],
                vec![batch, d],
            )?;
            Ok(PipelineExecutor::new(tag, adapter))
        }
    }
}

fn main() -> Result<()> {
    let mut rng = Pcg64::new(7);
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
    let grkan = RationalExecutor::new("grkan", 256, coeffs)?;
    let pipe = pipeline()?;
    let pipe_d = {
        use flashkat::serve::ModelExecutor;
        (pipe.d_in(), pipe.d_out())
    };

    let server = Server::start(
        vec![Box::new(grkan), Box::new(pipe)],
        BatchPolicy { max_batch: 16, deadline_us: 300, queue_depth: 256, eager: true },
    )?;
    for m in server.models() {
        println!("registered {:<10} {} -> {}", m.name, m.d_in, m.d_out);
    }

    // Concurrent clients, routed by model name.
    std::thread::scope(|s| {
        for client in 0..4u64 {
            let server = &server;
            s.spawn(move || {
                let mut rng = Pcg64::with_stream(7, client);
                for i in 0..25 {
                    let (name, d) =
                        if (client + i) % 2 == 0 { ("grkan", 256) } else { ("kat_micro", pipe_d.0) };
                    let rows = 1 + rng.below(3);
                    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                    let resp = server.submit(name, x, rows as u32).expect("served");
                    assert_eq!(resp.y.len() % rows, 0);
                }
            });
        }
    });

    let stats = server.shutdown().expect("stats");
    println!("\nper-model stats:");
    for m in &stats.per_model {
        println!(
            "  {:<10} requests {:>4}  rows {:>5}  batches {:>4}  mean batch {:>4.1}  busy {:>7.3} ms",
            m.name,
            m.stats.requests,
            m.stats.rows,
            m.stats.batches,
            m.stats.mean_batch(),
            m.stats.busy_secs * 1e3,
        );
    }
    let total = stats.total();
    println!(
        "total: {} requests in {} batches (peak queue {})",
        total.requests, total.batches, stats.peak_queued
    );
    Ok(())
}
